"""Kernel-geometry autotuner: bit-identity of tuned geometries, the
bitonic tile reducer, the tuning table, and the streaming build path."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, layouts, query
from repro.core.live_index import SegmentedIndex
from repro.kernels import autotune, ops
from repro.kernels.fused_decode_score import (
    _check_reducer, _tile_topk, _tile_topk_bitonic, build_batched_pairs,
    default_k_tile, fused_topk_blocked_pallas)
from repro.text import corpus


@pytest.fixture(autouse=True)
def _clean_table():
    """Every test starts from an empty active table (= historical
    defaults) and restores whatever was active before."""
    prev = autotune.set_active(None)
    yield
    autotune.set_active(prev)


# ---------------------------------------------------------------------------
# bitonic reducer: bit-identical (value, doc id) vs successive maxima
# ---------------------------------------------------------------------------


def _reduce_pair(final, base, k_tile, tile):
    sv, si = _tile_topk(jnp.asarray(final), base, k_tile, tile)
    bv, bi = _tile_topk_bitonic(jnp.asarray(final), base, k_tile, tile)
    return (np.asarray(sv), np.asarray(si)), (np.asarray(bv),
                                              np.asarray(bi))


def _assert_bit_identical(final, base, k_tile, tile):
    (sv, si), (bv, bi) = _reduce_pair(final, base, k_tile, tile)
    # bit-identical: values by bit pattern (not approx), ids exactly
    np.testing.assert_array_equal(sv.view(np.uint32), bv.view(np.uint32))
    np.testing.assert_array_equal(si, bi)


def test_bitonic_engineered_multi_tile_ties():
    """Many lanes share the max value: both reducers must break ties
    toward the LOWEST lane (global doc id), in the same order."""
    q, tile, k_tile = 4, 256, 16
    final = np.full((q, tile), -np.inf, np.float32)
    final[:, ::7] = 1.0          # 37 tied lanes per row
    final[:, 128:136] = 2.5      # 8 tied maxima mid-tile
    final[1] = 0.25              # a full row of one value
    _assert_bit_identical(final, 512, k_tile, tile)


def test_bitonic_all_neg_inf_tile():
    """A garbage tile (every lane -inf) must yield id -1 everywhere."""
    final = np.full((3, 128), -np.inf, np.float32)
    (sv, si), (bv, bi) = _reduce_pair(final, 0, 8, 128)
    np.testing.assert_array_equal(si, -1)
    np.testing.assert_array_equal(bi, -1)
    np.testing.assert_array_equal(sv.view(np.uint32), bv.view(np.uint32))


def test_bitonic_requires_pow2_tile():
    with pytest.raises(ValueError):
        _tile_topk_bitonic(jnp.zeros((1, 96), jnp.float32), 0, 8, 96)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dep
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def tile_cases(draw):
        tile = draw(st.sampled_from([64, 128, 256, 512]))
        q = draw(st.integers(1, 5))
        k_tile = draw(st.integers(1, tile))
        kind = draw(st.sampled_from(["random", "ties", "sparse"]))
        seed = draw(st.integers(0, 2**16))
        rng = np.random.default_rng(seed)
        if kind == "random":
            final = rng.standard_normal((q, tile)).astype(np.float32)
        elif kind == "ties":
            vals = rng.choice(np.float32([0.0, 0.5, 1.0, 2.0]),
                              size=(q, tile))
            final = vals.astype(np.float32)
        else:
            final = np.full((q, tile), -np.inf, np.float32)
            n_live = draw(st.integers(0, tile))
            idx = rng.choice(tile, size=n_live, replace=False)
            final[:, idx] = rng.standard_normal(
                (q, n_live)).astype(np.float32)
        base = draw(st.sampled_from([0, tile, 7 * tile]))
        return final, base, k_tile, tile

    @settings(max_examples=40, deadline=None)
    @given(case=tile_cases())
    def test_bitonic_bit_identical_property(case):
        """PROPERTY: for any tile content — random, heavy ties, mostly
        -inf — the bitonic partial sort returns bit-identical (value,
        global doc id) candidates to the successive-maxima loop."""
        _assert_bit_identical(*case)


# ---------------------------------------------------------------------------
# non-default tile geometry: k_tile clamp + engine parity
# ---------------------------------------------------------------------------


def test_default_k_tile_clamps_to_tile():
    assert default_k_tile(10) == 16
    assert default_k_tile(10, tile=256) == 16
    # k wider than a narrow tile: clamp, never exceed the tile width
    assert default_k_tile(300, tile=256) == 256
    assert default_k_tile(300, tile=256, k_pad=64) == 256


def test_k_tile_above_tile_rejected():
    from repro.kernels.fused_decode_score import _check_k_tile
    with pytest.raises(ValueError):
        _check_k_tile(512, 256)
    with pytest.raises(ValueError):
        _check_k_tile(0, 256)
    _check_k_tile(256, 256)  # boundary OK


def _small_index(layout="hor"):
    tc = corpus.generate(corpus.CorpusSpec(num_docs=700, vocab=900,
                                           avg_distinct=30, seed=13))
    host = build.bulk_build(tc)
    ix = (layouts.build_packed_csr(host) if layout == "packed"
          else layouts.build_blocked(host))
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=5)
    return host, ix, qh


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_non_default_tile_ranks_identically(layout):
    """Regression for the default_k_tile/tile interaction: a tuned
    non-default tile (256 and 1024) must rank exactly like the default
    512 geometry."""
    host, ix, qh = _small_index(layout)
    cap = host.max_posting_len
    ref, _ = query.fused_score_queries(ix, jnp.asarray(qh), k=10, cap=cap,
                                       backend="xla")
    for tile in (256, 1024):
        tuned, _ = query.fused_score_queries(
            ix, jnp.asarray(qh), k=10, cap=cap, backend="xla",
            tune=autotune.TuneConfig(tile=tile))
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(tuned.doc_ids))
        np.testing.assert_allclose(np.asarray(ref.scores),
                                   np.asarray(tuned.scores),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("cfg", [
    autotune.TuneConfig(reducer="bitonic"),
    autotune.TuneConfig(pairs_per_step=2),
    autotune.TuneConfig(pairs_per_step=4, reducer="bitonic"),
    autotune.TuneConfig(q_pad=16),
    autotune.TuneConfig(k_tile=32),
])
def test_tuned_geometry_bit_parity(cfg):
    """Geometries that keep the tile width must be BIT-identical to the
    default config (identical candidates up to k_tile width)."""
    host, ix, qh = _small_index("hor")
    cap = host.max_posting_len
    ref, _ = query.fused_score_queries(ix, jnp.asarray(qh), k=10, cap=cap,
                                       backend="xla")
    tuned, _ = query.fused_score_queries(ix, jnp.asarray(qh), k=10,
                                         cap=cap, backend="xla", tune=cfg)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(tuned.doc_ids))
    np.testing.assert_array_equal(
        np.asarray(ref.scores).view(np.uint32),
        np.asarray(tuned.scores).view(np.uint32))


def test_active_table_changes_make_scorer_geometry():
    """Installing a tuned table changes the geometry make_scorer bakes
    in — and results stay identical to the default geometry."""
    host, ix, qh = _small_index("hor")
    cap = host.max_posting_len
    base = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                             backend="xla")(jnp.asarray(qh))
    table = autotune.TuningTable()
    table.put("xla", autotune.size_class_of(int(ix.docs.num_docs)), "hor",
              autotune.TuneConfig(reducer="bitonic", pairs_per_step=2))
    prev = autotune.set_active(table)
    try:
        tuned = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                                  backend="xla")(jnp.asarray(qh))
    finally:
        autotune.set_active(prev)
    np.testing.assert_array_equal(np.asarray(base.doc_ids),
                                  np.asarray(tuned.doc_ids))
    np.testing.assert_array_equal(
        np.asarray(base.scores).view(np.uint32),
        np.asarray(tuned.scores).view(np.uint32))


# ---------------------------------------------------------------------------
# tuning table
# ---------------------------------------------------------------------------


def test_tuning_table_roundtrip(tmp_path):
    t = autotune.TuningTable()
    t.put("pallas", 2048, "hor",
          autotune.TuneConfig(tile=1024, pairs_per_step=2))
    t.put("xla", 512, "packed", autotune.TuneConfig(reducer="bitonic"))
    p = tmp_path / "table.json"
    t.save(str(p))
    t2 = autotune.TuningTable.load(str(p))
    assert t2.get("pallas", 2048, "hor") == autotune.TuneConfig(
        tile=1024, pairs_per_step=2)
    assert t2.get("xla", 512, "packed") == autotune.TuneConfig(
        reducer="bitonic")
    # schema check refuses foreign files
    bad = {"schema": "other/9", "entries": []}
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps(bad))
    with pytest.raises(ValueError):
        autotune.TuningTable.load(str(p2))


def test_lookup_falls_back_to_smaller_class_then_default():
    t = autotune.TuningTable()
    cfg = autotune.TuneConfig(pairs_per_step=2)
    t.put("pallas", autotune.size_class_of(1000), "hor", cfg)
    # bigger class inherits the nearest smaller tuned class
    assert t.lookup("pallas", 500_000, "hor") == cfg
    # different layout / backend fall through to the defaults
    assert t.lookup("pallas", 500_000, "packed") == autotune.DEFAULT_CONFIG
    assert t.lookup("xla", 500_000, "hor") == autotune.DEFAULT_CONFIG


def test_empty_table_resolves_to_historical_defaults():
    assert autotune.lookup("pallas", 123_456, "hor") == \
        autotune.DEFAULT_CONFIG
    assert autotune.DEFAULT_CONFIG.tile == 512
    assert autotune.DEFAULT_CONFIG.q_pad == 8
    assert autotune.DEFAULT_CONFIG.k_pad == 8
    assert autotune.DEFAULT_CONFIG.reducer == "successive"
    assert autotune.DEFAULT_CONFIG.pairs_per_step == 1


def test_reducer_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_REDUCER", "bitonic")
    assert autotune.lookup("pallas", 1000, "hor").reducer == "bitonic"
    monkeypatch.setenv("REPRO_REDUCER", "nope")
    with pytest.raises(ValueError):
        autotune.lookup("pallas", 1000, "hor")


def test_autotune_index_selects_and_stores_winner():
    host, ix, qh = _small_index("hor")
    idf_w = jnp.log1p(
        host.num_docs / jnp.maximum(
            jnp.asarray(np.where(qh > 0, 3.0, 0.0)), 1.0))
    table = autotune.TuningTable()
    configs = [autotune.DEFAULT_CONFIG,
               autotune.TuneConfig(pairs_per_step=2)]
    best, records = autotune.autotune_index(
        ix, jnp.asarray(qh), idf_w, k=10, backend="xla",
        configs=configs, reps=1, warmup=1, table=table)
    assert len(records) == 2
    assert all(r["median_s"] > 0 for r in records)
    assert {tuple(sorted(r["config"].items())) for r in records} == \
        {tuple(sorted(c.to_dict().items())) for c in configs}
    stored = table.get("xla", autotune.size_class_of(int(ix.docs.num_docs)),
                       "hor")
    assert stored == best


# ---------------------------------------------------------------------------
# streaming build: bounded-RAM path is exact
# ---------------------------------------------------------------------------


def _live_topk_ids(si, qh, k=10):
    r = si.topk(qh, k, backend="xla")
    return np.asarray(r.doc_ids), np.asarray(r.scores)


def test_streaming_build_matches_bulk_ingest():
    """stream_batches + deferred-norm add_batch + one final
    refresh_norms ranks bit-identically to per-batch refreshes of the
    same stream."""
    spec = corpus.CorpusSpec(num_docs=900, vocab=700, avg_distinct=25,
                             seed=21)

    def build_si(refresh_each):
        si = SegmentedIndex(delta_doc_capacity=256,
                            delta_posting_capacity=256 * 64)
        for b in corpus.stream_batches(spec, batch_docs=200):
            si.add_batch(b, refresh_norms=refresh_each)
        si.seal()
        si.refresh_norms()
        return si

    eager = build_si(True)
    deferred = build_si(False)
    assert eager.num_docs == deferred.num_docs == spec.num_docs
    qh = corpus.sample_query_terms(
        np.asarray(eager.view().df), np.asarray(eager.view().hashes),
        6, 3, num_docs=spec.num_docs, seed=9)
    ei, es = _live_topk_ids(eager, qh)
    di, ds = _live_topk_ids(deferred, qh)
    np.testing.assert_array_equal(ei, di)
    np.testing.assert_array_equal(es.view(np.uint32), ds.view(np.uint32))


def test_stream_batches_reproducible_for_fixed_batching():
    """The stream is a pure function of (spec, batch_docs): rerunning
    with the SAME batching replays the exact corpus.  (Changing
    batch_docs reseeds every draw — only distributional statistics are
    batching-independent; see the stream_batches docstring.)"""
    spec = corpus.CorpusSpec(num_docs=500, vocab=400, avg_distinct=20,
                             seed=4)
    a = list(corpus.stream_batches(spec, batch_docs=125))
    b = list(corpus.stream_batches(spec, batch_docs=125))
    assert sum(x.num_docs for x in a) == spec.num_docs
    for x, y in zip(a, b):
        for tx, ty in zip(x.doc_term_ids, y.doc_term_ids):
            np.testing.assert_array_equal(tx, ty)
        for cx, cy in zip(x.doc_counts, y.doc_counts):
            np.testing.assert_array_equal(cx, cy)


def test_live_view_with_tuned_table_matches_default():
    """A live index mixing sealed segments + delta must rank
    identically when the active table swaps every segment to a tuned
    geometry."""
    spec = corpus.CorpusSpec(num_docs=600, vocab=500, avg_distinct=22,
                             seed=17)
    si = SegmentedIndex(delta_doc_capacity=128,
                        delta_posting_capacity=128 * 64)
    for b in corpus.stream_batches(spec, batch_docs=150):
        si.add_batch(b)
    qh = corpus.sample_query_terms(
        np.asarray(si.view().df), np.asarray(si.view().hashes), 5, 3,
        num_docs=spec.num_docs, seed=2)
    base_i, base_s = _live_topk_ids(si, qh)
    table = autotune.TuningTable()
    for cls in {autotune.size_class_of(int(s.index.docs.num_docs))
                for s in si.segments()}:
        table.put("xla", cls, "hor",
                  autotune.TuneConfig(reducer="bitonic", pairs_per_step=2,
                                      k_tile=32))
    prev = autotune.set_active(table)
    try:
        tuned_i, tuned_s = _live_topk_ids(si, qh)
    finally:
        autotune.set_active(prev)
    np.testing.assert_array_equal(base_i, tuned_i)
    np.testing.assert_array_equal(base_s.view(np.uint32),
                                  tuned_s.view(np.uint32))


# ---------------------------------------------------------------------------
# pairs_per_step budget widening: run-aligned padding must never drop
# real routing pairs
# ---------------------------------------------------------------------------


def test_padded_pairs_budget_covers_run_alignment():
    """Regression: a budget that is EXACT at pps == 1 (route_pairs_max
    at the route tile, reached by querying every term at full cap)
    overflows under pps == 2 run-aligned no-op padding — (2600 docs,
    80 terms, seed 1) is a corpus where the old round_up-only budget
    demonstrably drops a real pair.  ``padded_pairs_budget`` must not."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=2600, vocab=80,
                                           avg_distinct=20, seed=1))
    host = build.bulk_build(tc)
    ix = layouts.build_blocked(host)
    cap = host.max_posting_len
    th = host.term_hashes
    qh = jnp.asarray(th[th != 0][None, :])
    t_ids = jnp.where(qh != 0, ix.lookup_terms(qh), -1)
    m = min(max(-(-cap // ix.block), 1), max(ix.max_blocks_per_term, 1))
    cb, cv, cq, cw, cc = ops.expand_block_candidates(
        ix.block_offsets, t_ids, jnp.ones_like(t_ids, jnp.float32), m,
        ix.block, cap)
    tf, tcn, n_tiles = ops.routing_spans(ix, 512)

    def overflow_at(mp):
        *_, ovf = build_batched_pairs(
            cb, cv, cq, cw.astype(jnp.float32), tf, tcn, n_tiles, 1, mp,
            cand_cap=cc, pairs_per_step=2)
        return int(ovf)

    narrow = ops.round_up_pairs(ops.scaled_pairs_budget(ix, 512), 2)
    assert overflow_at(narrow) > 0          # the pre-fix budget
    assert overflow_at(ops.padded_pairs_budget(ix, 512, 2)) == 0


def test_live_view_tuned_pps_no_silent_drop():
    """LiveView.topk under a pps > 1 tuned geometry must process the
    FULL pair set (overflow 0, bit-identical ranking) — and the
    default stats-free path must route the summed overflow through the
    loud-overflow contract rather than silently discarding it."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=700, vocab=150,
                                           avg_distinct=25, seed=2))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=256,
                        delta_posting_capacity=256 * 64)
    si.add_batch(tc)
    si.seal()
    th = np.asarray(si.view().hashes)
    qh = th[th != 0][None, :].astype(np.uint32)
    ref = si.topk(qh, 10)
    tuned, stats = si.topk(qh, 10,
                           tune=autotune.TuneConfig(pairs_per_step=2),
                           return_stats=True)
    assert stats["pair_overflow"] == 0
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(tuned.doc_ids))
    np.testing.assert_array_equal(
        np.asarray(ref.scores).view(np.uint32),
        np.asarray(tuned.scores).view(np.uint32))
    # stats-free path: warn_on_overflow runs (no-op at 0) and the
    # ranking is unchanged
    quiet = si.topk(qh, 10, tune=autotune.TuneConfig(pairs_per_step=2))
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(quiet.doc_ids))


# ---------------------------------------------------------------------------
# bitonic reducer is interpret-only until the j == 1 exchange is
# Mosaic-legal
# ---------------------------------------------------------------------------


def test_bitonic_reducer_refused_on_compiled_lowering():
    _check_reducer("bitonic", True)          # interpret mode allowed
    _check_reducer("successive", False)      # compiled successive allowed
    with pytest.raises(NotImplementedError):
        _check_reducer("bitonic", False)
    # the kernel entry point enforces it at trace time, before any
    # Mosaic lowering can fail or miscompile
    with pytest.raises(NotImplementedError):
        fused_topk_blocked_pallas(
            jnp.zeros((1, 8), jnp.int32), jnp.zeros((1, 8), jnp.float32),
            jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32),
            jnp.zeros((2, 8), jnp.float32), jnp.zeros((2,), jnp.int32),
            jnp.ones((16,), jnp.float32), jnp.zeros((16,), jnp.float32),
            jnp.ones((8,), jnp.float32), 16, 8, tile=16,
            reducer="bitonic", interpret=False)


def test_poisoned_table_bitonic_downgrades_on_compiled_lowering():
    """A tuning table carrying reducer='bitonic' for a compiled
    (non-interpret) lowering must not detonate at kernel entry: lookup
    downgrades the entry to 'successive' (counter + one-shot warning),
    on exact hits AND nearest-smaller-class inheritance, while
    interpret-capable backends keep the tuned reducer.  The env
    override bypasses the downgrade, so the kernel's hard guard stays
    the backstop."""
    import warnings

    from repro.obs.registry import GLOBAL

    t = autotune.TuningTable()
    t.put("pallas-tpu", 2048, "hor", autotune.TuneConfig(reducer="bitonic"))
    t.put("xla", 2048, "hor", autotune.TuneConfig(reducer="bitonic"))
    counter = GLOBAL.counter("autotune_bitonic_downgrade")
    c0 = counter.value
    autotune._BITONIC_WARNED = False
    with pytest.warns(RuntimeWarning, match="bitonic"):
        cfg = t.lookup("pallas-tpu", 2048, "hor")       # exact class
    assert cfg.reducer == "successive"
    with warnings.catch_warnings():
        warnings.simplefilter("error")                  # one-shot only
        cfg = t.lookup("pallas-tpu", 500_000, "hor")    # inherited class
    assert cfg.reducer == "successive"
    assert counter.value == c0 + 2
    # interpret-capable lowerings keep the tuned (bit-identical) reducer
    assert t.lookup("xla", 2048, "hor").reducer == "bitonic"
    # the downgrade never rewrites the stored entry
    assert t.get("pallas-tpu", 2048, "hor").reducer == "bitonic"

    # REPRO_REDUCER=bitonic bypasses table resolution entirely — the
    # kernel-entry hard guard still refuses the compiled lowering
    with pytest.raises(NotImplementedError):
        fused_topk_blocked_pallas(
            jnp.zeros((1, 8), jnp.int32), jnp.zeros((1, 8), jnp.float32),
            jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32),
            jnp.zeros((2, 8), jnp.float32), jnp.zeros((2,), jnp.int32),
            jnp.ones((16,), jnp.float32), jnp.zeros((16,), jnp.float32),
            jnp.ones((8,), jnp.float32), 16, 8, tile=16,
            reducer="bitonic", interpret=False)
