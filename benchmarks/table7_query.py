"""Paper Table 7: query evaluation times for 1–4 term queries, per
representation x lookup kind, plus the Pallas blocked-scoring path.

Mirrors §4.3's protocol: frequent terms (df band), batched queries,
median steady-state time per query.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_host, emit, time_call
from repro.core import layouts, query
from repro.core.query import idf as idf_fn
from repro.kernels import ops
from repro.text import corpus

N_QUERIES = 8
# fused-engine routing budget per query-term count (overflow-checked
# below: the emitted pair_overflow field must stay 0)
MAX_PAIRS_PER_TERM = 512


def main() -> None:
    _, host = bench_host()
    cap = host.max_posting_len
    indexes = {
        "pr_btree": layouts.build_coo(host),
        "pr_hash": layouts.build_coo(host, lookup="hash"),
        "or_btree": layouts.build_csr(host),
        "or_hash": layouts.build_csr(host, lookup="hash"),
        "cor": layouts.build_compact_csr(host),
        "hor": layouts.build_blocked(host),
        "packed": layouts.build_packed_csr(host),
    }

    pr_time = {}
    for n_terms in (1, 2, 3, 4):
        qh = corpus.sample_query_terms(host.df, host.term_hashes,
                                       N_QUERIES, n_terms,
                                       num_docs=host.num_docs,
                                       seed=n_terms)
        jnp_time = {}
        for name, ix in indexes.items():
            scorer = query.make_scorer(ix, k=10, cap=cap)
            us = time_call(scorer, jnp.asarray(qh)) / N_QUERIES
            jnp_time[name] = us
            if name == "pr_btree":
                pr_time[n_terms] = us
            emit(f"table7/{name}/{n_terms}t", us,
                 f"speedup_vs_pr={pr_time[n_terms] / us:.2f}")

        # Batched fused decode-and-score engine: routing pairs are
        # deduplicated across the whole batch, so a hot posting block is
        # read once for every query touching it.  CPU wall-time uses the
        # engine's plain-HLO lowering (backend="xla", same dedup +
        # wide-row scatter); the Pallas kernel itself is timed below in
        # interpret mode (NOT hardware-representative).  max_pairs is the
        # engine's routing budget — the overflow counter verifies it.
        # Both ranking tails are timed: "dense" (full [Q, num_docs]
        # score array + top_k) and "candidates" (per-tile partial top-k
        # + candidate merge — the HBM-write win is on real TPU; on CPU
        # this row just verifies the tail costs about the same).
        for name in ("hor", "packed"):
            for mode in ("candidates", "dense"):
                fused = query.make_scorer(
                    indexes[name], k=10, cap=cap, engine="pallas",
                    backend="xla", mode=mode,
                    max_pairs=MAX_PAIRS_PER_TERM * n_terms,
                    return_stats=True)
                _, stats = fused(jnp.asarray(qh))
                us = time_call(lambda q: fused(q)[0],
                               jnp.asarray(qh)) / N_QUERIES
                emit(f"table7/fused_{name}_{mode}_b{N_QUERIES}/"
                     f"{n_terms}t", us,
                     f"speedup_vs_jnp={jnp_time[name] / us:.2f};"
                     f"pair_overflow={int(stats['pair_overflow'])}")

        # legacy single-query kernel glue via the XLA oracle path
        hor = indexes["hor"]
        q0 = jnp.asarray(qh[0])
        tids = hor.lookup_terms(q0)
        w = idf_fn(hor.term_df(tids), host.num_docs)
        us = time_call(
            lambda t, ww: ops.blocked_query_scores(
                hor, t, ww, hor.max_blocks_per_term,
                max_pairs=16384, backend="xla"),
            tids, w)
        emit(f"table7/kernel_xla/{n_terms}t", us, "per_query")

    # one interpret-mode timing of the real fused Pallas kernel in
    # candidate mode (kernel SEMANTICS on CPU; wall time is the Python
    # interpreter's, not HBM's)
    qh1 = corpus.sample_query_terms(host.df, host.term_hashes, N_QUERIES, 1,
                                    num_docs=host.num_docs, seed=1)
    fused_pl = query.make_scorer(indexes["hor"], k=10, cap=cap,
                                 engine="pallas")
    us = time_call(fused_pl, jnp.asarray(qh1), reps=1, warmup=1) / N_QUERIES
    emit("table7/fused_hor_pallas_interp_candidates/1t", us,
         "interpret_mode=not_hw_representative")

    emit("table7/paper_measured", 0.0,
         "pr_4t_ms=143491;orif_4t_ms=13076;speedup=11.0")


if __name__ == "__main__":
    main()
