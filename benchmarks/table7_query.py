"""Paper Table 7: query evaluation times for 1–4 term queries, per
representation x lookup kind, plus the Pallas blocked-scoring path.

Mirrors §4.3's protocol: frequent terms (df band), batched queries,
median steady-state time per query.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_host, emit, time_call
from repro.core import layouts, query
from repro.core.query import idf as idf_fn
from repro.kernels import ops
from repro.text import corpus

N_QUERIES = 8


def main() -> None:
    _, host = bench_host()
    cap = host.max_posting_len
    indexes = {
        "pr_btree": layouts.build_coo(host),
        "pr_hash": layouts.build_coo(host, lookup="hash"),
        "or_btree": layouts.build_csr(host),
        "or_hash": layouts.build_csr(host, lookup="hash"),
        "cor": layouts.build_compact_csr(host),
        "hor": layouts.build_blocked(host),
        "packed": layouts.build_packed_csr(host),
    }

    pr_time = {}
    for n_terms in (1, 2, 3, 4):
        qh = corpus.sample_query_terms(host.df, host.term_hashes,
                                       N_QUERIES, n_terms,
                                       num_docs=host.num_docs,
                                       seed=n_terms)
        for name, ix in indexes.items():
            scorer = query.make_scorer(ix, k=10, cap=cap)
            us = time_call(scorer, jnp.asarray(qh)) / N_QUERIES
            if name == "pr_btree":
                pr_time[n_terms] = us
            emit(f"table7/{name}/{n_terms}t", us,
                 f"speedup_vs_pr={pr_time[n_terms] / us:.2f}")

        # Pallas fused blocked scoring (the TPU hot-path kernel,
        # interpret-mode on CPU so time is NOT hardware-representative;
        # reported for completeness, roofline covers the TPU story)
        hor = indexes["hor"]
        q0 = jnp.asarray(qh[0])
        tids = hor.lookup_terms(q0)
        w = idf_fn(hor.term_df(tids), host.num_docs)
        us = time_call(
            lambda t, ww: ops.blocked_query_scores(
                hor, t, ww, hor.max_blocks_per_term,
                max_pairs=16384, backend="xla"),
            tids, w)
        emit(f"table7/kernel_xla/{n_terms}t", us, "per_query")

    emit("table7/paper_measured", 0.0,
         "pr_4t_ms=143491;orif_4t_ms=13076;speedup=11.0")


if __name__ == "__main__":
    main()
