"""Serving benchmark: offered-QPS sweep over the QueryServer.

A closed-loop driver paces single-query submissions at each offered
rate while the worker thread micro-batches them and a maintenance
thread seals/compacts behind pinned epochs; a background ingest stream
advances the epoch so the cache invalidation path is exercised, and the
query stream draws from a finite pool so repeats produce cache hits.

Emits (CSV rows via benchmarks.common.emit):

  serving/qps_N     value = p50 request latency at offered rate N;
                    derived = p50/p99/mean (common.latency_summary, the
                    same helper churn.py reports with) + achieved QPS,
                    cache hit rate, batch fill, epochs served
  serving/lifecycle seals/compactions the maintenance thread ran and
                    the final segment count

``--smoke`` (or run.py --smoke) shrinks the sweep to a plumbing check;
the long sweep is exercised by the slow-marked test in
tests/test_serve.py (the daily full-suite job).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.serve import IndexMaintenance, QueryServer, ServerConfig
from repro.text import corpus


def _build_live_index(tc, holdback_frac=0.25, delta_docs=128):
    """Ingest all but a holdback slice (streamed during the drive)."""
    n = tc.num_docs
    first = int(n * (1 - holdback_frac))
    si = SegmentedIndex(
        term_hashes=tc.term_hashes, delta_doc_capacity=delta_docs,
        delta_posting_capacity=delta_docs * 64,
        policy=compaction.TieredPolicy(size_ratio=8.0, min_run=4))
    step = max(first // 8, 1)
    for a in range(0, first, step):
        b = min(a + step, first)
        si.add_batch(build.TokenizedCorpus(tc.doc_term_ids[a:b],
                                           tc.doc_counts[a:b],
                                           tc.term_hashes, b - a))
    return si, first


def run_sweep(rates, n_requests, *, pool_size=64, ingest_every=64,
              tc=None, host=None, seed=11):
    """Drive the server at each offered rate; returns one summary dict
    per rate (keys: offered_qps + ServerMetrics.summary fields)."""
    if tc is None or host is None:
        tc, host = common.bench_host()
    si, ingested = _build_live_index(tc)
    # every request sampled: the sweep reports a per-stage latency
    # breakdown (queue wait / assemble / score / respond) per offered
    # rate, so saturation shows WHERE the time went, not just that p99
    # grew
    cfg = ServerConfig(batch_size=8, n_terms_budget=8, k=10,
                       trace_sample=1)
    server = QueryServer(si, cfg)
    maint = IndexMaintenance(si, server.index_lock, seal_fill=0.5,
                             interval_s=0.001)
    server.warmup()
    pool = corpus.sample_query_terms(host.df, host.term_hashes,
                                     pool_size, 3,
                                     num_docs=host.num_docs, seed=seed)
    rng = np.random.default_rng(seed)
    holdback = list(range(ingested, tc.num_docs,
                          max((tc.num_docs - ingested) // 16, 1)))

    results = []
    server.start()
    maint.start()
    try:
        for rate in rates:
            server.metrics.reset()
            server.cache.reset_counters()
            server.stages.reset()
            gap = 1.0 / rate if rate > 0 else 0.0
            tickets = []
            next_ingest = ingest_every
            for i in range(n_requests):
                tickets.append(server.submit(pool[rng.integers(pool_size)]))
                if i == next_ingest and holdback:
                    # one ingest batch mid-drive: epoch advances, cache
                    # entries of older epochs become unreachable
                    a = holdback.pop(0)
                    b = min(a + 16, tc.num_docs)
                    with server.index_lock:
                        si.add_batch(build.TokenizedCorpus(
                            tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a))
                    next_ingest += ingest_every
                if gap:
                    time.sleep(gap)
            for t in tickets:
                t.result(timeout=120.0)
            s = server.metrics.summary()
            s["offered_qps"] = rate
            s["samples_us"] = server.metrics.latency.samples_us()
            s["stages"] = server.stage_summary()
            results.append(s)
    finally:
        maint.stop()
        server.stop()
    results.append({"lifecycle": {"maint_seals": maint.stats.seals,
                                  "maint_compactions":
                                      maint.stats.compactions,
                                  "segments": si.num_segments,
                                  "epoch": si.epoch}})
    return results


def _stage_fragment(stages: dict) -> str:
    """``score_p50=..us respond_p50=..us`` derived-column fragment —
    the dominant stages of the breakdown, CSV-greppable per rate."""
    parts = []
    for stage in ("queue_wait", "assemble", "score", "respond"):
        st = stages.get(stage)
        if st and st.get("count"):
            parts.append(f"{stage}_p50={st['p50']:.1f}us")
    return " ".join(parts)


def main() -> None:
    tc, host = common.bench_host()
    smoke = common.is_smoke()
    rates = [100, 400] if smoke else [50, 200, 800, 3200]
    n_requests = 96 if smoke else 512
    results = run_sweep(rates, n_requests, tc=tc, host=host)
    artifact = []
    for s in results:
        if "lifecycle" in s:
            lc = s["lifecycle"]
            common.emit("serving/lifecycle", 0.0,
                        f"maint_seals={lc['maint_seals']} "
                        f"maint_compactions={lc['maint_compactions']} "
                        f"segments={lc['segments']} epoch={lc['epoch']}")
            artifact.append(s)
            continue
        common.emit(
            f"serving/qps_{s['offered_qps']}", s["p50_us"],
            f"{common.latency_summary(s['samples_us'])} "
            f"achieved_qps={s['qps']:.0f} "
            f"hit_rate={s['cache_hit_rate']:.2f} "
            f"batch_fill={s['batch_fill']:.2f} "
            f"epochs={s['epochs_served']} "
            f"{_stage_fragment(s.get('stages', {}))}")
        # raw per-request samples stay out of the artifact (the
        # summary percentiles carry the signal at 1/1000 the bytes)
        artifact.append({k: v for k, v in s.items() if k != "samples_us"})
    common.write_bench(
        "serving", results={"sweep": artifact},
        config={"rates": rates, "n_requests": n_requests,
                "smoke": smoke})


if __name__ == "__main__":
    common.set_smoke()
    main()
