"""Serving benchmark: offered-QPS sweeps over QueryServer and MeshServer.

A closed-loop driver paces single-query submissions at each offered
rate while the worker thread micro-batches them and a maintenance
thread seals/compacts behind pinned epochs; a background ingest stream
advances the epoch so the cache invalidation path is exercised, and the
query stream draws from a finite pool so repeats produce cache hits.

The mesh sweep repeats the drive against a ``MeshServer`` per shard
count — each shard count in its own subprocess, since the XLA host
device count must be set before jax initialises — with admission
control and deadline shedding armed, ingest churn forcing epoch
handoffs mid-drive, and per-tenant cache traffic.

Emits (CSV rows via benchmarks.common.emit):

  serving/qps_N          value = p50 request latency at offered rate N;
                         derived = p50/p99/mean (common.latency_summary,
                         the same helper churn.py reports with) +
                         achieved QPS, cache hit rate, batch fill,
                         epochs served
  serving/lifecycle      seals/compactions the maintenance thread ran
                         and the final segment count
  serving/mesh_sS_qps_N  value = p50 mesh request latency at offered
                         rate N over S shards; derived adds shed rate,
                         handoff count + pause percentiles, and the
                         per-stage breakdown

``--smoke`` (or run.py --smoke) shrinks both sweeps to a plumbing
check; the long sweeps are exercised by the slow-marked tests in
tests/test_serve.py (the daily full-suite job).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks import common
from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.serve import IndexMaintenance, QueryServer, ServerConfig
from repro.text import corpus


def _build_live_index(tc, holdback_frac=0.25, delta_docs=128):
    """Ingest all but a holdback slice (streamed during the drive)."""
    n = tc.num_docs
    first = int(n * (1 - holdback_frac))
    si = SegmentedIndex(
        term_hashes=tc.term_hashes, delta_doc_capacity=delta_docs,
        delta_posting_capacity=delta_docs * 64,
        policy=compaction.TieredPolicy(size_ratio=8.0, min_run=4))
    step = max(first // 8, 1)
    for a in range(0, first, step):
        b = min(a + step, first)
        si.add_batch(build.TokenizedCorpus(tc.doc_term_ids[a:b],
                                           tc.doc_counts[a:b],
                                           tc.term_hashes, b - a))
    return si, first


def run_sweep(rates, n_requests, *, pool_size=64, ingest_every=64,
              tc=None, host=None, seed=11):
    """Drive the server at each offered rate; returns one summary dict
    per rate (keys: offered_qps + ServerMetrics.summary fields)."""
    if tc is None or host is None:
        tc, host = common.bench_host()
    si, ingested = _build_live_index(tc)
    # every request sampled: the sweep reports a per-stage latency
    # breakdown (queue wait / assemble / score / respond) per offered
    # rate, so saturation shows WHERE the time went, not just that p99
    # grew
    cfg = ServerConfig(batch_size=8, n_terms_budget=8, k=10,
                       trace_sample=1)
    server = QueryServer(si, cfg)
    maint = IndexMaintenance(si, server.index_lock, seal_fill=0.5,
                             interval_s=0.001)
    server.warmup()
    pool = corpus.sample_query_terms(host.df, host.term_hashes,
                                     pool_size, 3,
                                     num_docs=host.num_docs, seed=seed)
    rng = np.random.default_rng(seed)
    holdback = list(range(ingested, tc.num_docs,
                          max((tc.num_docs - ingested) // 16, 1)))

    results = []
    server.start()
    maint.start()
    try:
        for rate in rates:
            server.metrics.reset()
            server.cache.reset_counters()
            server.stages.reset()
            gap = 1.0 / rate if rate > 0 else 0.0
            tickets = []
            next_ingest = ingest_every
            for i in range(n_requests):
                tickets.append(server.submit(pool[rng.integers(pool_size)]))
                if i == next_ingest and holdback:
                    # one ingest batch mid-drive: epoch advances, cache
                    # entries of older epochs become unreachable
                    a = holdback.pop(0)
                    b = min(a + 16, tc.num_docs)
                    with server.index_lock:
                        si.add_batch(build.TokenizedCorpus(
                            tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a))
                    next_ingest += ingest_every
                if gap:
                    time.sleep(gap)
            for t in tickets:
                t.result(timeout=120.0)
            s = server.metrics.summary()
            s["offered_qps"] = rate
            s["samples_us"] = server.metrics.latency.samples_us()
            s["stages"] = server.stage_summary()
            results.append(s)
    finally:
        maint.stop()
        server.stop()
    results.append({"lifecycle": {"maint_seals": maint.stats.seals,
                                  "maint_compactions":
                                      maint.stats.compactions,
                                  "segments": si.num_segments,
                                  "epoch": si.epoch}})
    return results


# -- mesh sweep ------------------------------------------------------------
#
# One subprocess per shard count (XLA host device count is fixed at jax
# init); sizing/rates injected via .replace() like partitioned.py — the
# child regenerates the deterministic corpus rather than importing
# benchmarks, so only src/ needs to be on its path.  Each rate's summary
# comes back as one parseable ``MESHROW <json>`` line; the parent
# salvages partial output on timeout and names every dropped config.
MESH_SCRIPT = r"""
import dataclasses, json, time
import jax, numpy as np
from repro.text import corpus
from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.serve import MeshConfig, MeshServer

N_SHARDS = {shards}
mesh = jax.make_mesh((N_SHARDS,), ("shards",))
tc = corpus.generate(corpus.CorpusSpec(num_docs={docs}, vocab={vocab},
                                       avg_distinct={avg}, seed=42))
host = build.bulk_build(tc)

# ingest all but a holdback slice (streamed during the drive), sealing
# per step so the doc topology has segment runs to shard
n = tc.num_docs
first = int(n * 0.75)
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                    delta_posting_capacity=128 * 64,
                    policy=compaction.TieredPolicy(size_ratio=8.0,
                                                   min_run=4))
step = max(first // 8, 1)
for a in range(0, first, step):
    b = min(a + step, first)
    si.add_batch(dataclasses.replace(tc, doc_term_ids=tc.doc_term_ids[a:b],
                                     doc_counts=tc.doc_counts[a:b],
                                     num_docs=b - a))
    si.seal()

cfg = MeshConfig(batch_size=8, n_terms_budget=8, k=10, trace_sample=1,
                 n_shards=N_SHARDS, max_queue=64, deadline_us=500_000.0,
                 auto_handoff=True, handoff_min_interval_s=0.02,
                 seal_fill=0.5, maintenance_interval_s=0.002)
ms = MeshServer(si, cfg, mesh=mesh)
ms.warmup()
pool = corpus.sample_query_terms(host.df, host.term_hashes, 64, 3,
                                 num_docs=host.num_docs, seed=11)
rng = np.random.default_rng(11)
holdback = list(range(first, n, max((n - first) // 16, 1)))

ms.start()
try:
    for rate in {rates}:
        shed0 = ms.shed_counts()
        hand0 = ms.registry.histogram("mesh_handoff_pause_us").snapshot()
        ms.metrics.reset()
        ms.cache.reset_counters()
        ms.stages.reset()
        gap = 1.0 / rate
        tickets = []
        next_ingest = 24
        for i in range({requests}):
            tickets.append(ms.submit(pool[rng.integers(64)],
                                     tenant="t%d" % (i % 4)))
            if i == next_ingest and holdback:
                a = holdback.pop(0)
                b = min(a + 16, n)
                ms.add_batch(dataclasses.replace(
                    tc, doc_term_ids=tc.doc_term_ids[a:b],
                    doc_counts=tc.doc_counts[a:b], num_docs=b - a))
                next_ingest += 24
            time.sleep(gap)
        for t in tickets:
            t.result(timeout=120.0)
        s = ms.metrics.summary()
        shed1 = ms.shed_counts()
        hand1 = ms.registry.histogram("mesh_handoff_pause_us").snapshot()
        shed = {k: shed1[k] - shed0[k] for k in shed1}
        offered = s["requests"] + shed["total"]
        row = {"offered_qps": rate, "n_shards": N_SHARDS,
               "offered": offered, "served": s["requests"],
               "p50_us": s["p50_us"], "p99_us": s["p99_us"],
               "achieved_qps": s["qps"], "shed": shed,
               "shed_rate": shed["total"] / offered if offered else 0.0,
               "handoffs": hand1["count"] - hand0["count"],
               "handoff_pause_p50_us": hand1.get("p50", 0.0),
               "handoff_pause_p99_us": hand1.get("p99", 0.0),
               "cache_hit_rate": s["cache_hit_rate"],
               "batch_fill": s["batch_fill"],
               "epochs_served": s["epochs_served"],
               "stages": ms.stage_summary()}
        print("MESHROW " + json.dumps(row), flush=True)
finally:
    ms.stop()
print("MESHDONE", flush=True)
"""


def run_mesh_sweep(shard_counts, rates, n_requests):
    """Offered-QPS x shard-count sweep over the MeshServer, one
    subprocess per shard count.  Returns ``(rows, dropped)``: per-rate
    summary dicts (MESHROW payloads) and the explicitly-named configs a
    timeout or crash left unmeasured."""
    spec = common.SMOKE_SPEC if common.is_smoke() else common.BENCH_SPEC
    sizing = dict(docs=spec.num_docs, vocab=spec.vocab,
                  avg=spec.avg_distinct)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    rows, dropped = [], []
    for n_shards in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_shards}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        script = MESH_SCRIPT
        for key, val in dict(sizing, shards=n_shards, rates=list(rates),
                             requests=n_requests).items():
            script = script.replace("{%s}" % key, str(val))
        try:
            out = subprocess.run([sys.executable, "-c", script],
                                 env=env, capture_output=True, text=True,
                                 timeout=520)
            stdout, stderr = out.stdout, out.stderr
        except subprocess.TimeoutExpired as e:
            # salvage the rates that finished before the budget ran out
            stdout = (e.stdout or b"").decode() if isinstance(
                e.stdout, bytes) else (e.stdout or "")
            err = (e.stderr or b"").decode() if isinstance(
                e.stderr, bytes) else (e.stderr or "")
            stderr = "subprocess timeout: " + err
        finished = []
        for line in stdout.splitlines():
            if line.startswith("MESHROW "):
                row = json.loads(line[len("MESHROW "):])
                rows.append(row)
                finished.append(row["offered_qps"])
        # a salvage that silently drops configs reads as "all measured"
        for rate in rates:
            if rate not in finished:
                dropped.append({"n_shards": n_shards, "offered_qps": rate})
        if not finished:
            emit_tail = stderr[-200:].replace("\n", " ")
            common.emit(f"serving/mesh_s{n_shards}/FAILED", 0.0, emit_tail)
    return rows, dropped


def _mesh_fragment(row: dict) -> str:
    return (f"p99={row['p99_us']:.1f}us "
            f"achieved_qps={row['achieved_qps']:.0f} "
            f"shed_rate={row['shed_rate']:.3f} "
            f"handoffs={row['handoffs']} "
            f"handoff_pause_p50={row['handoff_pause_p50_us']:.0f}us "
            f"hit_rate={row['cache_hit_rate']:.2f} "
            f"{_stage_fragment(row.get('stages', {}))}")


def _stage_fragment(stages: dict) -> str:
    """``score_p50=..us respond_p50=..us`` derived-column fragment —
    the dominant stages of the breakdown, CSV-greppable per rate (the
    mesh-only stages print only when the mesh sweep observed them)."""
    parts = []
    for stage in ("queue_wait", "handoff", "assemble", "score",
                  "respond", "shed"):
        st = stages.get(stage)
        if st and st.get("count"):
            parts.append(f"{stage}_p50={st['p50']:.1f}us")
    return " ".join(parts)


def main() -> None:
    tc, host = common.bench_host()
    smoke = common.is_smoke()
    rates = [100, 400] if smoke else [50, 200, 800, 3200]
    n_requests = 96 if smoke else 512
    results = run_sweep(rates, n_requests, tc=tc, host=host)
    artifact = []
    for s in results:
        if "lifecycle" in s:
            lc = s["lifecycle"]
            common.emit("serving/lifecycle", 0.0,
                        f"maint_seals={lc['maint_seals']} "
                        f"maint_compactions={lc['maint_compactions']} "
                        f"segments={lc['segments']} epoch={lc['epoch']}")
            artifact.append(s)
            continue
        common.emit(
            f"serving/qps_{s['offered_qps']}", s["p50_us"],
            f"{common.latency_summary(s['samples_us'])} "
            f"achieved_qps={s['qps']:.0f} "
            f"hit_rate={s['cache_hit_rate']:.2f} "
            f"batch_fill={s['batch_fill']:.2f} "
            f"epochs={s['epochs_served']} "
            f"{_stage_fragment(s.get('stages', {}))}")
        # raw per-request samples stay out of the artifact (the
        # summary percentiles carry the signal at 1/1000 the bytes)
        artifact.append({k: v for k, v in s.items() if k != "samples_us"})

    # sharded closed-loop sweep: offered QPS x shard count
    # full-mode sizing stays modest: each shard count is one subprocess
    # on a 520s budget, and interpret-mode scoring at the bench corpus
    # is ~10s/batch — the DROPPED salvage names anything that overruns
    mesh_shards = [1, 2] if smoke else [1, 2, 4]
    mesh_rates = [100, 400] if smoke else [50, 200, 800]
    mesh_requests = 64 if smoke else 128
    mesh_rows, mesh_dropped = run_mesh_sweep(mesh_shards, mesh_rates,
                                             mesh_requests)
    for row in mesh_rows:
        common.emit(
            f"serving/mesh_s{row['n_shards']}_qps_{row['offered_qps']}",
            row["p50_us"], _mesh_fragment(row))
    for d in mesh_dropped:
        common.emit(
            f"serving/mesh_s{d['n_shards']}_qps_{d['offered_qps']}/DROPPED",
            0.0, "timed_out_before_measurement")
    common.write_bench(
        "serving",
        results={"sweep": artifact,
                 "mesh": {"rows": mesh_rows, "dropped": mesh_dropped}},
        config={"rates": rates, "n_requests": n_requests,
                "mesh": {"shard_counts": mesh_shards,
                         "rates": mesh_rates,
                         "n_requests": mesh_requests},
                "smoke": smoke})


if __name__ == "__main__":
    common.set_smoke()
    main()
