# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

  table5   paper Table 5 (sizes + copy times)
  table6   paper Table 6 (aux-index sizes + creation)
  table7   paper Table 7 (query evaluation, 1-4 terms)
  expansion  paper §4.4 (document-based access)
  roofline   §Roofline terms from the dry-run artifacts (if present)
  churn    live-index ingest/churn: docs/sec, latency vs segment count,
           posting-merge amplification vs full rebuild
  serving  QueryServer offered-QPS sweep: request latency p50/p99,
           achieved QPS, cache hit rate, maintenance-thread lifecycle;
           plus the MeshServer offered-QPS x shard-count sweep (shed
           rate, handoff pause) — one subprocess per shard count

``--smoke`` runs every suite on a CI-sized corpus (plumbing check, not
representative numbers).
"""
from __future__ import annotations

import dataclasses
import sys
import traceback


def main() -> None:
    from benchmarks import churn, common, expansion, partitioned, \
        roofline, serving, table5_size, table6_index, table7_query
    suites = [("table5", table5_size.main), ("table6", table6_index.main),
              ("table7", table7_query.main), ("expansion", expansion.main),
              ("partitioned", partitioned.main),
              ("roofline", roofline.main), ("churn", churn.main),
              ("serving", serving.main)]
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    if smoke:
        args.remove("--smoke")
        common.set_smoke()
    only = args[0] if args else None
    print("name,us_per_call,derived")
    common.reset_records()
    failed = 0
    for name, fn in suites:
        if only and name != only:
            continue
        try:
            fn()
        except Exception:                        # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,")
    if smoke:
        # the artifact CI gates on: suite CSV rows + a dedicated
        # fused-scorer latency measurement (schema-versioned JSON);
        # v3 adds the observability section — a traced serving drive's
        # per-stage breakdown + the unified registry snapshot — and,
        # additively, the mesh section: a deterministic MeshServer
        # drive's shed counts/rate, handoff pauses, and stage
        # breakdown (check_regression.check_mesh_section)
        gate = common.smoke_gate_stats()
        obs = common.smoke_observability()
        common.write_bench(
            "smoke",
            results={"gate": gate, "suites_failed": failed,
                     "layout_mix": common.smoke_layout_mix(),
                     "stages": obs["stages"],
                     "registry": obs["registry"],
                     "mesh": common.smoke_mesh()},
            config={"spec": dataclasses.asdict(common.SMOKE_SPEC),
                    "only": only})
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
