"""Ingest/churn benchmark: the segmented live index vs batch rebuild.

Emits (CSV rows via benchmarks.common.emit):

  churn/live_ingest        us per batch, derived docs/sec sustained
  churn/rebuild_ingest     us per batch for the §3.6 merge-everything
                           path (the pre-live-index ``add_documents``)
  churn/query_segments_N   fused multi-segment query latency with N
                           sealed segments on the stack (value = p50;
                           derived carries p50/p99/mean — percentile
                           reporting shared with benchmarks/serving.py
                           via common.latency_summary)
  churn/amplification      posting-merge work ratio rebuild/live —
                           cumulative postings touched per path (the
                           ISSUE's >= 10x criterion is on the per-batch
                           steady state, reported in ``derived``)
  churn/lifecycle          seals + compactions the schedule triggered

``--smoke`` shrinks the schedule but still exercises seal + compact +
delete + multi-segment query end to end (the CI plumbing check).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.text import corpus


def _batches(tc, n_batches):
    bounds = np.linspace(0, tc.num_docs, n_batches + 1).astype(int)
    return [build.TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                                  tc.term_hashes, b - a)
            for a, b in zip(bounds[:-1], bounds[1:])]


def main() -> None:
    tc, host_full = common.bench_host()
    smoke = common.is_smoke()
    n_batches = 8 if smoke else 32
    batches = _batches(tc, n_batches)
    per_batch = batches[0].num_docs
    qh = corpus.sample_query_terms(host_full.df, host_full.term_hashes,
                                   4, 3, num_docs=host_full.num_docs,
                                   seed=7)

    # --- live path: delta appends + seals + tiered compaction ----------
    si = SegmentedIndex(
        term_hashes=tc.term_hashes,
        delta_doc_capacity=max(per_batch // 2, 32),
        delta_posting_capacity=max(per_batch * 40, 2048),
        policy=compaction.TieredPolicy(size_ratio=8.0, min_run=8))
    checkpoints = sorted({n_batches // 4, n_batches // 2,
                          n_batches - 1} - {0})
    t0 = time.perf_counter()
    ingest_time = 0.0
    for i, b in enumerate(batches):
        t1 = time.perf_counter()
        si.add_batch(b)
        if i == n_batches // 2:          # churn: deletes mixed in
            si.delete(np.arange(0, si.num_docs, max(si.num_docs // 64, 1)))
        ingest_time += time.perf_counter() - t1
        if i in checkpoints:
            reps = 5 if smoke else 20
            samples = common.time_samples(lambda: si.topk(qh, k=10),
                                          reps=reps, warmup=1)
            common.emit(f"churn/query_segments_{si.num_segments}",
                        float(np.median(samples)),
                        f"{common.latency_summary(samples)} "
                        f"delta_docs={si._delta.n_docs}")
    live_us = ingest_time / n_batches * 1e6
    common.emit("churn/live_ingest", live_us,
                f"docs_per_sec={per_batch / (ingest_time / n_batches):.0f}")

    # --- rebuild baseline: merge ALL postings every batch --------------
    t2 = time.perf_counter()
    host = build.bulk_build(batches[0])
    rebuild_touched = host.num_postings
    for b in batches[1:]:
        host = build._merge_documents(host, b, host.num_docs)
        rebuild_touched += host.num_postings
    rebuild_time = time.perf_counter() - t2
    rebuild_us = rebuild_time / n_batches * 1e6
    common.emit("churn/rebuild_ingest", rebuild_us,
                f"docs_per_sec={per_batch / (rebuild_time / n_batches):.0f}")

    # --- amplification: posting-merge work, cumulative + steady-state --
    live_touched = si.stats.postings_merged
    cum_ratio = rebuild_touched / max(live_touched, 1)
    # steady state: last batch of the rebuild path touches every posting;
    # the live path's amortized per-batch merge work is its cumulative
    # total over the batch count
    steady = host.num_postings / max(live_touched / n_batches, 1)
    common.emit("churn/amplification", 0.0,
                f"cumulative={cum_ratio:.1f}x steady_state={steady:.1f}x "
                f"appended={si.stats.postings_appended}")
    common.emit("churn/lifecycle", 0.0,
                f"seals={si.stats.seals} compactions={si.stats.compactions}"
                f" segments={si.num_segments} live={si.live_doc_count}")
    _ = t0


if __name__ == "__main__":
    common.set_smoke()
    main()
