"""Paper §4.4: query-expansion (document-based access) times.

The paper: PR without a doc-access path degenerates to a sequential
scan (~16 h); ORIF ~20 min; the proposed fix is a DIRECT (forward)
index.  We measure all three access paths on the bench tier.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_host, emit, time_call
from repro.core import direct_index, layouts, query
from repro.text import corpus


def main() -> None:
    _, host = bench_host()
    cap = host.max_posting_len
    orx = layouts.build_csr(host)
    prx = layouts.build_coo(host)
    di = direct_index.build_direct(host)

    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 2,
                                   num_docs=host.num_docs, seed=7)[0]
    r = query.score_query(orx, jnp.asarray(qh), k=5, cap=cap)
    top = r.doc_ids

    import jax
    scan_pr = jax.jit(lambda docs: direct_index.expand_query_scan(
        prx, docs, host.num_terms))
    scan_or = jax.jit(lambda docs: direct_index.expand_query_scan(
        orx, docs, host.num_terms))
    fast = jax.jit(lambda docs: direct_index.expand_query(
        di, docs, host.num_terms, cap=di.max_doc_len))

    us_pr = time_call(scan_pr, top)
    us_or = time_call(scan_or, top)
    us_di = time_call(fast, top)
    emit("expansion/pr_full_scan", us_pr, "paper:~16h at 1M docs")
    emit("expansion/orif_scan", us_or, "paper:~19.8min at 1M docs")
    emit("expansion/direct_index", us_di,
         f"speedup_vs_scan={us_or / us_di:.1f};direct_bytes={di.nbytes()}")

    # relevance feedback via the same access path
    tids = orx.lookup_terms(jnp.asarray(qh))
    fb = jax.jit(lambda docs: direct_index.relevance_feedback(
        di, docs, tids, host.num_terms, cap=di.max_doc_len))
    emit("expansion/relevance_feedback", time_call(fb, top), "rocchio")


if __name__ == "__main__":
    main()
