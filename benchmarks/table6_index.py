"""Paper Table 6: auxiliary-index (term-lookup structure) sizes and
creation times — B+tree (sorted) vs Hash, per representation."""
from __future__ import annotations

from benchmarks.common import bench_host, emit, time_host
from repro.core import layouts


def main() -> None:
    _, host = bench_host()

    # PR / OR carry a separate word table -> both lookup kinds
    for name in ("btree", "hash"):
        us = time_host(
            lambda n=name: (layouts.build_sorted_lookup(host.term_hashes)
                            if n == "btree"
                            else layouts.build_hash_lookup(host.term_hashes)),
            reps=3)
        lk = (layouts.build_sorted_lookup(host.term_hashes)
              if name == "btree"
              else layouts.build_hash_lookup(host.term_hashes))
        emit(f"table6/lookup/{name}", us, f"bytes={lk.nbytes()}")

    # COR/HOR fold the lookup into the occurrence relation: creation time
    # is the hash-sort of the vocabulary (part of the build); report the
    # incremental cost and size (the sorted_hash column).
    import numpy as np
    us = time_host(lambda: np.argsort(host.term_hashes, kind="stable"),
                   reps=3)
    emit("table6/lookup/cor_folded", us,
         f"bytes={host.term_hashes.nbytes}")

    # paper's measured observation: B+ half the size of Hash, both fast
    emit("table6/paper_measured", 0.0,
         "btree_pages=2928;hash_pages=6716;ratio=2.3")


if __name__ == "__main__":
    main()
