"""Million-page Zipfian scale campaign with tracked BENCH artifacts.

Builds a live index at three tiers — 10k / 100k / 1M documents — by
STREAMING the synthetic corpus through ``SegmentedIndex.add_batch``
(``text.corpus.stream_batches``: host RAM stays bounded by one batch no
matter the tier; norms are refreshed once after the final seal instead
of per batch, which is bit-identical and turns the quadratic rescan
into a single pass), then measures:

  build    docs/sec, wall seconds, peak RSS (ru_maxrss), segments,
           postings, compaction amplification
  autotune the kernel-geometry sweep (``kernels.autotune``) on the
           largest sealed segment, on the Pallas/interpret backend —
           the tier where per-grid-step overhead makes non-default
           geometry win; the winning table is installed + saved
  query    fused candidates engine p50/p99 per batch size and terms/
           query (plain-HLO ``backend="xla"`` lowering for CPU wall
           time), with analytic bytes/query from core.size_model
  serving  QueryServer micro-drive: request latency p50/p99, achieved
           QPS, batch fill

Each tier writes a schema-versioned ``BENCH_campaign_<tier>.json`` (see
``benchmarks.common.write_bench``); the autotune sweep writes
``BENCH_autotune.json`` and the winning ``TUNED_cpu.json`` table.  CI's
daily job runs the 100k tier; the 1M tier is the committed-artifact
campaign run.

  PYTHONPATH=src python -m benchmarks.campaign --tier 10k
  PYTHONPATH=src python -m benchmarks.campaign --tier all --out DIR
"""
from __future__ import annotations

import argparse
import dataclasses
import resource
import time

import numpy as np

from benchmarks import common
from repro.core import size_model
from repro.core.live_index import SegmentedIndex
from repro.kernels import autotune
from repro.text import corpus

# Tier specs keep the paper's posting-length REGIME (df of a frequent
# term ~ 0.3*D) while scaling docs; 1M matches the paper's D=1,004,721.
TIERS = {
    "10k": corpus.CorpusSpec(num_docs=10_000, vocab=4_000,
                             avg_distinct=40, seed=7),
    "100k": corpus.CorpusSpec(num_docs=100_000, vocab=20_000,
                              avg_distinct=48, seed=7),
    "1m": corpus.CorpusSpec(num_docs=1_004_721, vocab=50_000,
                            avg_distinct=40, seed=7),
}
BATCH_DOCS = {"10k": 5_000, "100k": 25_000, "1m": 50_000}
QUERY_REPS = {"10k": 20, "100k": 10, "1m": 5}
TUNE_REPS = {"10k": 3, "100k": 2, "1m": 1}
SERVE_REQUESTS = {"10k": 160, "100k": 96, "1m": 48}

# Interpret-mode probe: the Pallas kernel in interpret mode executes
# one Python step per routing pair, so the sweep runs on a small sealed
# segment (~2k-doc class) — per-grid-step overhead is exactly the cost
# the winning geometry amortizes, and ``TuningTable.lookup`` lets every
# LARGER size class inherit the winner until swept directly.
PROBE_SPEC = corpus.CorpusSpec(num_docs=1_500, vocab=600,
                               avg_distinct=25, seed=7)


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def build_streaming(spec: corpus.CorpusSpec, batch_docs: int,
                    delta_docs: int = 16_384,
                    layout_policy: size_model.LayoutCostModel | None = None,
                    ) -> tuple[SegmentedIndex, dict]:
    """Stream-build a sealed SegmentedIndex; returns (index, stats).

    ``layout_policy=None`` keeps the historical hor-everywhere build
    (bit-identical to pre-chooser campaigns); passing a
    ``LayoutCostModel`` routes every seal/compaction through the
    override ladder, and the converged mix lands in the artifact."""
    si = SegmentedIndex(delta_doc_capacity=delta_docs,
                        delta_posting_capacity=delta_docs * 64,
                        seal_layout="hor", layout_policy=layout_policy)
    rss0 = _peak_rss_mb()
    t0 = time.perf_counter()
    n_batches = 0
    for batch in corpus.stream_batches(spec, batch_docs):
        si.add_batch(batch, refresh_norms=False)
        n_batches += 1
    si.seal()
    si.refresh_norms()
    wall = time.perf_counter() - t0
    postings = sum(si.segment_postings())
    stats = {
        "docs": si.num_docs,
        "postings": int(postings),
        "batches": n_batches,
        "batch_docs": batch_docs,
        "wall_s": round(wall, 2),
        "docs_per_sec": round(si.num_docs / max(wall, 1e-9), 1),
        "segments": si.num_segments,
        "postings_merged": int(si.stats.postings_merged),
        "merge_amplification": round(
            si.stats.postings_merged / max(postings, 1), 2),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
        "peak_rss_delta_mb": round(_peak_rss_mb() - rss0, 1),
    }
    return si, stats


def _layout_report(si: SegmentedIndex) -> dict:
    """Converged layout mix + per-segment byte roofline.

    For every sealed segment: the measured posting-array bytes, the
    EXACT hor bytes the same postings would occupy
    (``size_model.hor_posting_bytes_from_df`` over the segment's df),
    and their ratio — the campaign's acceptance check that
    chooser-selected packed segments really serve <= ~0.5x the HOR
    posting traffic per query, not just that the chooser fired."""
    mix = si.layout_mix()
    segs = []
    for seg in si.segments():
        hor_exact = size_model.hor_posting_bytes_from_df(
            np.asarray(seg.index.df))
        measured = seg.index.posting_bytes()
        rec = {
            "layout": seg.layout,
            "size_class": int(seg.size_class),
            "docs": int(seg.doc_span),
            "postings": int(seg.n_postings),
            "reason": seg.chooser_reason,
            "posting_bytes": int(measured),
            "hor_posting_bytes": int(hor_exact),
            "bytes_vs_hor": round(measured / max(hor_exact, 1), 3),
        }
        if seg.layout == "packed":
            # per ROUTED BLOCK: what a query actually streams from HBM
            # for each block its terms touch (same block boundaries in
            # both layouts, so this IS the bytes/query ratio) — the
            # array-total ratio above additionally counts rare-term
            # blocks no frequent-term query reads
            block = int(seg.index.block_tfs.shape[1])
            per_packed = int(seg.index.packed.shape[1]) * 4 + block * 2 + 12
            per_hor = block * 8 + 8
            rec["block_bytes_vs_hor"] = round(per_packed / per_hor, 3)
        elif seg.layout == "banded":
            # same per-routed-block roofline, but PER BAND: the packed
            # band's stride is band-local (the dense-body shape), so
            # its ratio can fall well below the monolithic-packed
            # floor; the HOR tail streams HOR blocks by construction
            ix = seg.index
            block = int(ix.packed.block_tfs.shape[1])
            per_hor = block * 8 + 8
            per_packed = int(ix.packed.packed.shape[1]) * 4 + block * 2 + 12
            rec["band_cut"] = int(seg.band_cut)
            rec["bands"] = {
                "packed": {
                    "terms": int(np.count_nonzero(
                        np.asarray(ix.packed.df))),
                    "posting_bytes": int(ix.packed.posting_bytes()),
                    "block_bytes_vs_hor": round(per_packed / per_hor, 3),
                },
                "hor": {
                    "terms": int(np.count_nonzero(np.asarray(ix.hor.df))),
                    "posting_bytes": int(ix.hor.posting_bytes()),
                    "block_bytes_vs_hor": 1.0,
                },
            }
        segs.append(rec)
    return {"counts": mix["counts"], "docs": mix["docs"],
            "postings": mix["postings"], "reasons": mix["reasons"],
            "segments": segs}


def _query_pool(view, num_queries: int, terms_per_query: int,
                seed: int = 11) -> np.ndarray:
    return corpus.sample_query_terms(
        np.asarray(view.df), np.asarray(view.hashes), num_queries,
        terms_per_query, num_docs=max(int(view.live_docs), 1), seed=seed)


def _sweep_segment(si: SegmentedIndex, k: int, reps: int,
                   backend: str) -> dict:
    """Sweep the geometry grid on the LARGEST sealed segment (the class
    every other segment compacts toward); install the winner in the
    active table."""
    view = si.view()
    seg = max(si.segments(), key=lambda s: int(s.index.docs.num_docs))
    qh, _, idf_w, _ = view._prep(_query_pool(view, 8, 3))
    table = autotune.get_active()
    best, records = autotune.autotune_index(
        seg.index, qh, idf_w, k, backend=backend, reps=reps, table=table)
    default_rec = next(r for r in records if r["is_default"])
    best_rec = next(r for r in records if r["config"] == best.to_dict())
    return {
        "backend": backend,
        "segment_docs": int(seg.index.docs.num_docs),
        "size_class": autotune.size_class_of(int(seg.index.docs.num_docs)),
        "layout": seg.layout,
        "best": best.to_dict(),
        "best_is_default": bool(best == autotune.DEFAULT_CONFIG),
        "default_median_s": default_rec["median_s"],
        "best_median_s": best_rec["median_s"],
        "speedup_vs_default": round(
            default_rec["median_s"] / max(best_rec["median_s"], 1e-12), 3),
        "records": records,
    }


def run_autotune_probe(k: int = 10, reps: int = 3) -> dict:
    """The CPU/interpret autotune demonstration: sweep the Pallas
    kernel IN INTERPRET MODE on a small sealed probe segment.  Interpret
    mode pays Python per grid step, so pairs-per-step unrolling and
    wider tiles (fewer steps) win decisively over the TPU-default
    geometry — the campaign artifact records the non-default choice."""
    si, _ = build_streaming(PROBE_SPEC, PROBE_SPEC.num_docs,
                            delta_docs=8_192)
    return _sweep_segment(si, k, reps, backend="pallas")


def run_autotune(si: SegmentedIndex, tier: str, k: int = 10,
                 backend: str = "xla") -> dict:
    """Per-tier sweep on the tier's own largest segment under the
    plain-HLO lowering (CPU wall-time representative)."""
    return _sweep_segment(si, k, TUNE_REPS[tier], backend=backend)


def run_queries(si: SegmentedIndex, tier: str, k: int = 10,
                backend: str = "xla") -> dict:
    """Fused-candidates latency sweep over batch sizes and query widths,
    plus the analytic candidate-traffic roofline per query."""
    view = si.view()
    reps = QUERY_REPS[tier]
    out: dict = {"backend": backend, "k": k, "sweeps": []}
    for n_terms in (1, 3):
        pool = _query_pool(view, 32, n_terms, seed=100 + n_terms)
        for bs in (1, 8):
            qb = pool[:bs]
            samples = common.time_samples(
                lambda q: view.topk(q, k, backend=backend), qb,
                reps=reps, warmup=2)
            s = common.summary_stats(samples)
            s.update(batch=bs, terms_per_query=n_terms,
                     us_per_query=round(s["p50_us"] / bs, 1))
            out["sweeps"].append(s)
            common.emit(f"campaign/{tier}/query_b{bs}_{n_terms}t",
                        s["p50_us"] / bs, common.latency_summary(samples))
    # candidate bytes/query: what the in-kernel top-k writes to HBM in
    # place of the dense [num_docs] score row, per sealed segment at its
    # tuned geometry (the §Roofline traffic term the campaign tracks)
    cand_bytes = 0
    post_bytes = 0
    for seg in si.segments():
        nd = int(seg.index.docs.num_docs)
        cfg = autotune.lookup(backend, nd, seg.layout)
        cand_bytes += size_model.candidate_bytes_per_query(
            nd, cfg.tile, cfg.resolve_k_tile(k))
        post_bytes += 8 * int(np.asarray(seg.index.docs.norm).shape[0])
    out["candidate_bytes_per_query"] = int(cand_bytes)
    out["dense_score_bytes_per_query"] = int(
        4 * sum(int(s.index.docs.num_docs) for s in si.segments()))
    return out


def run_serving(si: SegmentedIndex, tier: str, backend: str = "xla") -> dict:
    """Closed-loop QueryServer micro-drive against the campaign index."""
    from repro.serve import QueryServer, ServerConfig

    n_requests = SERVE_REQUESTS[tier]
    # trace every request: the tier artifact carries WHERE serving time
    # goes (queue wait vs kernel vs merge), not just the e2e percentile
    cfg = ServerConfig(batch_size=8, n_terms_budget=8, k=10,
                       backend=backend, trace_sample=1)
    server = QueryServer(si, cfg)
    view = si.view()
    pool = _query_pool(view, 64, 3, seed=23)
    qb = np.zeros((len(pool), cfg.n_terms_budget), np.uint32)
    qb[:, : pool.shape[1]] = pool
    server.warmup()
    rng = np.random.default_rng(5)
    server.start()
    try:
        t0 = time.perf_counter()
        done = 0
        while done < n_requests:
            # waves of 2 micro-batches: latency reflects batching +
            # scoring, not an unbounded closed-loop submit queue
            wave = min(2 * cfg.batch_size, n_requests - done)
            tickets = [server.submit(qb[rng.integers(len(qb))])
                       for _ in range(wave)]
            for t in tickets:
                t.result(timeout=600.0)
            done += wave
        wall = time.perf_counter() - t0
    finally:
        server.stop()
    m = server.metrics.summary()
    samples = server.metrics.latency.samples_us()
    s = common.summary_stats(samples)
    s.update(requests=n_requests,
             achieved_qps=round(n_requests / max(wall, 1e-9), 1),
             cache_hit_rate=m.get("cache_hit_rate", 0.0),
             stages=server.stage_summary())
    common.emit(f"campaign/{tier}/serving", s["p50_us"],
                common.latency_summary(samples))
    return s


def run_tier(tier: str, *, out_dir: str | None = None, k: int = 10,
             do_autotune: bool = True, do_serving: bool = True) -> str:
    spec = TIERS[tier]
    common.reset_records()
    print(f"# campaign tier={tier} docs={spec.num_docs}")
    # campaign tiers run with the adaptive chooser ON (defaults): every
    # 16k-doc seal clears min_packed_docs, so the roofline winner is
    # chosen at seal time and the artifact records the converged mix
    si, build_stats = build_streaming(
        spec, BATCH_DOCS[tier], layout_policy=size_model.LayoutCostModel())
    common.emit(f"campaign/{tier}/build", build_stats["wall_s"] * 1e6,
                f"docs_per_sec={build_stats['docs_per_sec']};"
                f"segments={build_stats['segments']};"
                f"peak_rss_mb={build_stats['peak_rss_mb']}")
    results: dict = {"build": build_stats,
                     "layout_mix": _layout_report(si)}
    mix = results["layout_mix"]
    packed_ratios = [s["bytes_vs_hor"] for s in mix["segments"]
                     if s["layout"] == "packed"]
    band_ratios = [s["bands"]["packed"]["block_bytes_vs_hor"]
                   for s in mix["segments"] if s["layout"] == "banded"]
    common.emit(
        f"campaign/{tier}/layout_mix", 0.0,
        f"counts={mix['counts']};"
        f"max_packed_bytes_vs_hor="
        f"{max(packed_ratios) if packed_ratios else 'n/a'};"
        f"max_banded_block_bytes_vs_hor="
        f"{max(band_ratios) if band_ratios else 'n/a'}")
    if do_autotune:
        tune = run_autotune(si, tier, k=k)
        results["autotune"] = tune
        common.emit(f"campaign/{tier}/autotune",
                    tune["best_median_s"] * 1e6,
                    f"speedup_vs_default={tune['speedup_vs_default']};"
                    f"best_is_default={tune['best_is_default']}")
    results["query"] = run_queries(si, tier, k=k)
    if do_serving:
        results["serving"] = run_serving(si, tier)
    return common.write_bench(
        f"campaign_{tier}", results=results,
        config={"spec": dataclasses.asdict(spec),
                "batch_docs": BATCH_DOCS[tier], "k": k},
        out_dir=out_dir)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tier", default="10k",
                    choices=sorted(TIERS) + ["all"])
    ap.add_argument("--out", default=None, help="artifact directory "
                    "(default benchmarks/artifacts)")
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--no-serving", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the interpret-mode probe sweep")
    ap.add_argument("--save-table", default=None, metavar="PATH",
                    help="write the winning tuning table as JSON")
    args = ap.parse_args(argv)
    tiers = sorted(TIERS) if args.tier == "all" else [args.tier]
    autotune_results = {}
    if not args.no_probe and not args.no_autotune:
        common.reset_records()
        probe = run_autotune_probe()
        autotune_results["probe_interpret"] = probe
        common.emit("campaign/probe/autotune_interpret",
                    probe["best_median_s"] * 1e6,
                    f"speedup_vs_default={probe['speedup_vs_default']};"
                    f"best_is_default={probe['best_is_default']}")
    for tier in tiers:
        path = run_tier(tier, out_dir=args.out,
                        do_autotune=not args.no_autotune,
                        do_serving=not args.no_serving)
        doc = common.read_bench(path)
        if "autotune" in doc["results"]:
            autotune_results[tier] = doc["results"]["autotune"]
    if autotune_results:
        common.reset_records()
        common.write_bench(
            "autotune",
            results={"tiers": autotune_results,
                     "table": autotune.get_active().to_dict()},
            config={"tiers": tiers}, out_dir=args.out)
    if args.save_table:
        autotune.get_active().save(args.save_table)


if __name__ == "__main__":
    main()
