"""Shared benchmark utilities: corpus tiers, timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core import build
from repro.text import corpus

# CPU-runnable tier calibrated to the paper's posting-length REGIME
# (paper: N_d/W ~ 1100 postings/term, query df ~ 0.3*D): docs=20k,
# vocab=2k -> ~600 postings/term.  Paper-scale numbers are reproduced
# analytically via core/size_model (see DESIGN.md §8).
BENCH_SPEC = corpus.CorpusSpec(num_docs=20_000, vocab=2_000,
                               avg_distinct=60, seed=42)

# CI-sized tier: exercises every suite's plumbing in seconds
SMOKE_SPEC = corpus.CorpusSpec(num_docs=1_500, vocab=600,
                               avg_distinct=25, seed=42)

_HOST_CACHE = {}
_ACTIVE_SPEC = BENCH_SPEC


def set_smoke() -> None:
    """Switch every suite to the smoke-sized corpus (``run.py --smoke``)."""
    global _ACTIVE_SPEC
    _ACTIVE_SPEC = SMOKE_SPEC


def is_smoke() -> bool:
    return _ACTIVE_SPEC is SMOKE_SPEC


def bench_host(spec: corpus.CorpusSpec | None = None):
    spec = spec or _ACTIVE_SPEC
    key = (spec.num_docs, spec.vocab, spec.avg_distinct, spec.seed)
    if key not in _HOST_CACHE:
        tc = corpus.generate(spec)
        _HOST_CACHE[key] = (tc, build.bulk_build(tc))
    return _HOST_CACHE[key]


def time_samples(fn: Callable, *args, reps: int = 10,
                 warmup: int = 2) -> np.ndarray:
    """Per-call wall times in microseconds (jit-warmed), one sample per
    rep — feed to ``latency_summary`` for percentile reporting."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        ts.append(time.perf_counter() - t0)
    return np.asarray(ts) * 1e6


def time_call(fn: Callable, *args, reps: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-warmed)."""
    return float(np.median(time_samples(fn, *args, reps=reps,
                                        warmup=warmup)))


def latency_summary(samples_us) -> str:
    """``p50=..us p99=..us mean=..us`` derived-column fragment — the ONE
    latency-reporting format, shared by churn and the serving benchmark
    (percentile math lives in repro.serve.metrics so the benchmarks and
    the QueryServer's own metrics can never disagree)."""
    from repro.serve.metrics import percentiles
    p = percentiles(samples_us, (50, 99))
    mean = float(np.mean(np.asarray(list(samples_us), np.float64))) \
        if len(samples_us) else 0.0
    return (f"p50={p['p50']:.1f}us p99={p['p99']:.1f}us "
            f"mean={mean:.1f}us")


def time_host(fn: Callable, *args, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
