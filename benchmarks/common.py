"""Shared benchmark utilities: corpus tiers, timing, CSV emission, and
the schema-versioned ``BENCH_<name>.json`` artifact writer shared by the
smoke gate (``run.py --smoke``) and the scale campaign
(``benchmarks.campaign``)."""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Callable

import jax
import numpy as np

from repro.core import build
from repro.text import corpus

# Version of the BENCH_*.json artifact layout.  Bump when a field
# changes meaning; consumers (CI regression gate, trajectory plots)
# refuse mismatched schemas instead of misreading them.
# v2 adds the layout-mix fields (results.layout_mix, per-segment
# chooser decisions in the campaign tiers).  v3 adds observability:
# results.registry (the unified metrics-registry snapshot) and
# results.stages (per-stage serving latency percentiles) in the smoke
# artifact.  v1/v2 artifacts stay readable — every older field kept
# its meaning — so the committed baselines don't need a regeneration
# flag-day.
SCHEMA = "repro-bench/3"
READ_SCHEMAS = ("repro-bench/1", "repro-bench/2", "repro-bench/3")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# CPU-runnable tier calibrated to the paper's posting-length REGIME
# (paper: N_d/W ~ 1100 postings/term, query df ~ 0.3*D): docs=20k,
# vocab=2k -> ~600 postings/term.  Paper-scale numbers are reproduced
# analytically via core/size_model (see DESIGN.md §8).
BENCH_SPEC = corpus.CorpusSpec(num_docs=20_000, vocab=2_000,
                               avg_distinct=60, seed=42)

# CI-sized tier: exercises every suite's plumbing in seconds
SMOKE_SPEC = corpus.CorpusSpec(num_docs=1_500, vocab=600,
                               avg_distinct=25, seed=42)

_HOST_CACHE = {}
_ACTIVE_SPEC = BENCH_SPEC


def set_smoke() -> None:
    """Switch every suite to the smoke-sized corpus (``run.py --smoke``)."""
    global _ACTIVE_SPEC
    _ACTIVE_SPEC = SMOKE_SPEC


def is_smoke() -> bool:
    return _ACTIVE_SPEC is SMOKE_SPEC


def bench_host(spec: corpus.CorpusSpec | None = None):
    spec = spec or _ACTIVE_SPEC
    key = (spec.num_docs, spec.vocab, spec.avg_distinct, spec.seed)
    if key not in _HOST_CACHE:
        tc = corpus.generate(spec)
        _HOST_CACHE[key] = (tc, build.bulk_build(tc))
    return _HOST_CACHE[key]


def time_samples(fn: Callable, *args, reps: int = 10,
                 warmup: int = 2) -> np.ndarray:
    """Per-call wall times in microseconds (jit-warmed), one sample per
    rep — feed to ``latency_summary`` for percentile reporting."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        ts.append(time.perf_counter() - t0)
    return np.asarray(ts) * 1e6


def time_call(fn: Callable, *args, reps: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-warmed)."""
    return float(np.median(time_samples(fn, *args, reps=reps,
                                        warmup=warmup)))


def latency_summary(samples_us) -> str:
    """``p50=..us p99=..us mean=..us`` derived-column fragment — the ONE
    latency-reporting format, shared by churn and the serving benchmark
    (percentile math lives in repro.serve.metrics so the benchmarks and
    the QueryServer's own metrics can never disagree)."""
    from repro.serve.metrics import percentiles
    p = percentiles(samples_us, (50, 99))
    mean = float(np.mean(np.asarray(list(samples_us), np.float64))) \
        if len(samples_us) else 0.0
    return (f"p50={p['p50']:.1f}us p99={p['p99']:.1f}us "
            f"mean={mean:.1f}us")


def time_host(fn: Callable, *args, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# rows captured by emit() since the last reset — write_bench() snapshots
# them into the artifact so every suite's CSV line lands in the JSON too
_RECORDS: list[dict] = []


def reset_records() -> None:
    _RECORDS.clear()


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived})


def summary_stats(samples_us) -> dict:
    """p50/p99/mean in microseconds — the JSON twin of
    ``latency_summary`` (same percentile math)."""
    from repro.serve.metrics import percentiles
    a = np.asarray(list(samples_us), np.float64)
    p = percentiles(a, (50, 99))
    return {"p50_us": round(float(p["p50"]), 1),
            "p99_us": round(float(p["p99"]), 1),
            "mean_us": round(float(np.mean(a)) if len(a) else 0.0, 1),
            "reps": int(len(a))}


def bench_env() -> dict:
    """Machine/backend fingerprint stamped into every artifact, so a
    trajectory of BENCH files is only compared within like environments."""
    from repro.kernels.runtime import resolve_interpret
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "interpret": bool(resolve_interpret(None)),
        "cpu_count": os.cpu_count(),
    }


def write_bench(name: str, results: dict | None = None,
                config: dict | None = None,
                out_dir: str | None = None) -> str:
    """Write ``BENCH_<name>.json``: schema header, environment, the
    caller's structured results, and every CSV row emitted since the
    last ``reset_records()``.  Returns the path written."""
    out_dir = out_dir or ARTIFACT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    doc = {
        "schema": SCHEMA,
        "name": name,
        "env": bench_env(),
        "config": config or {},
        "results": results or {},
        "rows": list(_RECORDS),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path


def read_bench(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") not in READ_SCHEMAS:
        raise ValueError(
            f"{path}: schema {doc.get('schema')!r} not in {READ_SCHEMAS!r}")
    return doc


def smoke_layout_mix() -> dict:
    """Layout mix of an auto-layout streaming build over the smoke
    corpus — the plumbing check for the adaptive chooser (sealed runs
    stay hor below the threshold, the compacted merge flips packed),
    uploaded with the BENCH_smoke artifact so CI tracks the field."""
    from repro.core import size_model
    from repro.core.live_index import SegmentedIndex

    tc, _h = bench_host(SMOKE_SPEC)
    # smoke-sized threshold: seals (~500 docs) stay hor, the full
    # compaction (~1.5k docs) crosses it and converges packed
    si = SegmentedIndex(
        term_hashes=tc.term_hashes, delta_doc_capacity=512,
        delta_posting_capacity=512 * 64,
        layout_policy=size_model.LayoutCostModel(min_packed_docs=1024))
    import dataclasses as _dc
    step = 500
    for lo in range(0, tc.num_docs, step):
        hi = min(lo + step, tc.num_docs)
        si.add_batch(_dc.replace(
            tc, doc_term_ids=tc.doc_term_ids[lo:hi],
            doc_counts=tc.doc_counts[lo:hi], num_docs=hi - lo))
        si.seal()
    pre = si.layout_mix()
    si.compact(all_segments=True)
    post = si.layout_mix()

    # deliberate banded build over the same corpus: per-band posting
    # bytes against the exact HOR roofline (additive repro-bench/3
    # field; benchmarks.check_regression validates it when present)
    from repro.core import layouts
    bix = layouts.build_banded(_h)
    hor_exact = size_model.hor_posting_bytes_from_df(np.asarray(_h.df))
    words, nblocks = layouts.term_packed_words(_h)
    cut, _bytes = size_model.choose_band_cut(words, nblocks)
    banded = {
        "band_cut": int(cut),
        "packed_words_per_block": int(bix.packed.words_per_block),
        "posting_bytes": int(bix.posting_bytes()),
        "hor_posting_bytes": int(hor_exact),
        "bytes_vs_hor": round(bix.posting_bytes() / max(hor_exact, 1), 3),
        "bands": {
            "packed": {
                "terms": int(np.count_nonzero(np.asarray(bix.packed.df))),
                "posting_bytes": int(bix.packed.posting_bytes()),
                "bytes_vs_hor": round(
                    int(bix.packed.posting_bytes())
                    / max(size_model.hor_posting_bytes_from_df(
                        np.asarray(bix.packed.df)), 1), 3),
            },
            "hor": {
                "terms": int(np.count_nonzero(np.asarray(bix.hor.df))),
                "posting_bytes": int(bix.hor.posting_bytes()),
                "bytes_vs_hor": 1.0,
            },
        },
    }
    return {"sealed": {"counts": pre["counts"], "reasons": pre["reasons"]},
            "compacted": {"counts": post["counts"],
                          "reasons": post["reasons"]},
            "banded": banded}


def smoke_observability(n_requests: int = 48) -> dict:
    """Traced serving micro-drive over the smoke corpus: every request
    sampled, so the artifact carries the per-stage latency breakdown
    (queue wait / assembly / kernel / merge / respond) plus the full
    registry snapshot — the v3 observability section CI validates.

    The stage-sum invariant is asserted here too: a sampled response's
    top-level spans must sum to its measured e2e latency (within 5%,
    per the tracing contract; the construction makes it exact)."""
    from repro.core.live_index import SegmentedIndex
    from repro.serve import QueryServer, ServerConfig

    tc, h = bench_host(SMOKE_SPEC)
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=512,
                        delta_posting_capacity=512 * 64)
    si.add_batch(tc)
    si.seal()
    server = QueryServer(si, ServerConfig(
        batch_size=8, n_terms_budget=8, k=10, backend="xla",
        trace_sample=1))
    server.warmup()
    pool = corpus.sample_query_terms(h.df, h.term_hashes, 16, 3,
                                     num_docs=h.num_docs)
    tickets = [server.submit(pool[i % len(pool)])
               for i in range(n_requests)]
    while server.pending:
        server.pump()
    worst = 0.0
    for t in tickets:
        r = t.result(timeout=30.0)
        total = sum(r.trace.stage_durations().values())
        worst = max(worst, abs(total - r.latency_us) / max(r.latency_us,
                                                           1e-9))
    if worst > 0.05:
        raise AssertionError(
            f"stage spans sum to {worst:.1%} off the measured e2e "
            "latency — the shared-boundary tracing contract is broken")
    return {"stages": server.stage_summary(),
            "registry": server.metrics_snapshot(),
            "stage_sum_rel_err_max": worst,
            "requests": n_requests}


def smoke_mesh(n_requests: int = 32) -> dict:
    """Deterministic pump-driven MeshServer drive over the smoke
    corpus — the ``results.mesh`` section of BENCH_smoke.json that CI
    gates (``check_regression.check_mesh_section``).

    The drive is constructed so every gated field is non-trivially
    exercised without sleeps or threads: the admission queue is sized
    to the request count so four extra submits shed on "admission";
    two queued tickets are backdated past the deadline so the first
    batch sheds them on "deadline"; the holdback ingest advances the
    epoch mid-drive so the next micro-batch pays (and traces) a
    cross-shard handoff.  Every request is trace-sampled, so the stage
    breakdown includes the mesh-only ``shed`` and ``handoff`` stages,
    and shed traces obey the same stage-sum contract as served ones."""
    import dataclasses as _dc

    from repro.core.live_index import SegmentedIndex
    from repro.serve import MeshConfig, MeshServer

    tc, h = bench_host(SMOKE_SPEC)
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=512,
                        delta_posting_capacity=512 * 64)
    first = 1000
    si.add_batch(_dc.replace(tc, doc_term_ids=tc.doc_term_ids[:first],
                             doc_counts=tc.doc_counts[:first],
                             num_docs=first))
    si.seal()
    ms = MeshServer(si, MeshConfig(
        batch_size=8, n_terms_budget=8, k=10, trace_sample=1,
        n_shards=1, max_queue=n_requests, deadline_us=60e6,
        auto_handoff=True, handoff_min_interval_s=0.0))
    ms.warmup()
    pool = corpus.sample_query_terms(h.df, h.term_hashes, 16, 3,
                                     num_docs=h.num_docs)
    tickets = [ms.submit(pool[i % 16], tenant=f"t{i % 2}")
               for i in range(n_requests)]
    shed_tix = [ms.submit(pool[0]) for _ in range(4)]   # queue is full
    for t in tickets[:2]:
        t.t_submit -= 120.0          # past the 60s deadline at pickup
    ms.pump(max_batches=2)
    ms.add_batch(_dc.replace(tc, doc_term_ids=tc.doc_term_ids[first:],
                             doc_counts=tc.doc_counts[first:],
                             num_docs=tc.num_docs - first))
    while ms.pending:
        ms.pump()
    worst = 0.0
    for t in tickets + shed_tix:
        r = t.result(timeout=30.0)
        total = sum(r.trace.stage_durations().values())
        worst = max(worst, abs(total - r.latency_us) / max(r.latency_us,
                                                           1e-9))
    if worst > 0.05:
        raise AssertionError(
            f"mesh stage spans sum to {worst:.1%} off the measured e2e "
            "latency — the shared-boundary tracing contract is broken")
    summary = ms.mesh_summary()
    return {"requests": n_requests + len(shed_tix),
            "shed": ms.shed_counts(), "shed_rate": ms.shed_rate(),
            "handoffs": summary["handoffs"],
            "handoff_pause_us": summary["handoff_pause_us"],
            "stages": ms.stage_summary(),
            "stage_sum_rel_err_max": worst}


def smoke_gate_stats(reps: int = 30) -> dict:
    """The one number CI gates on: p50/p99 of the fused candidates
    scorer over the smoke corpus (jit-warmed, single process)."""
    import jax.numpy as jnp

    from repro.core import layouts, query
    tc, h = bench_host(SMOKE_SPEC)
    ix = layouts.build_blocked(h)
    qh = corpus.sample_query_terms(h.df, h.term_hashes, 8, 3,
                                   num_docs=h.num_docs)
    scorer = query.make_scorer(ix, k=10, cap=h.max_posting_len,
                               engine="pallas", backend="xla",
                               mode="candidates")
    samples = time_samples(scorer, jnp.asarray(qh), reps=reps, warmup=3)
    return summary_stats(samples)
