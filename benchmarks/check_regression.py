"""CI latency-regression gate over BENCH_smoke.json artifacts.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --current benchmarks/artifacts/BENCH_smoke.json \
      --baseline benchmarks/baselines/BENCH_smoke.json [--factor 2.0]

Compares the dedicated smoke-gate latency (``results.gate.p99_us``) of a
fresh run against the committed baseline and exits non-zero if the
fresh p99 exceeds ``factor`` times the baseline p99.  Both files must
carry a schema from ``benchmarks.common.READ_SCHEMAS`` (every version
in that tuple kept the gate fields' meaning) — anything else fails the
gate loudly instead of comparing incompatible numbers.

The default factor is deliberately loose (2x): shared CI runners are
noisy, and the gate exists to catch order-of-magnitude kernel
regressions (a geometry change that stops fusing, an accidental dense
fallback), not single-digit percentages — the campaign artifacts track
those.  Environments are fingerprinted (``env`` block); a backend
mismatch between baseline and current is also a loud failure, since
e.g. comparing a TPU baseline against a CPU run gates nothing.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import common


def check_registry_section(results: dict) -> list[str]:
    """Validate the v3 observability section: ``results.registry`` must
    be a non-empty dict of well-formed instrument snapshots (counter →
    int value, gauge → numeric value, histogram → numeric count/sum/
    p50/p99) and ``results.stages`` a dict of per-stage summaries.  A
    malformed section fails loudly — a half-written registry snapshot
    means the export contract broke, and silently gating on it would
    hide exactly the class of bug the section exists to surface."""
    problems: list[str] = []
    reg = results.get("registry")
    if not isinstance(reg, dict) or not reg:
        return [f"results.registry missing or empty ({type(reg).__name__})"
                " — v3 artifact without its observability section"]
    for name, snap in sorted(reg.items()):
        if not isinstance(snap, dict) or "type" not in snap:
            problems.append(f"registry[{name!r}]: not an instrument "
                            f"snapshot: {snap!r}")
            continue
        kind = snap["type"]
        if kind == "counter":
            if not isinstance(snap.get("value"), int):
                problems.append(f"registry[{name!r}]: counter value "
                                f"{snap.get('value')!r} is not an int")
        elif kind == "gauge":
            if not isinstance(snap.get("value"), (int, float)) \
                    or isinstance(snap.get("value"), bool):
                problems.append(f"registry[{name!r}]: gauge value "
                                f"{snap.get('value')!r} is not numeric")
        elif kind == "histogram":
            if not isinstance(snap.get("count"), int):
                problems.append(f"registry[{name!r}]: histogram count "
                                f"{snap.get('count')!r} is not an int")
            for field in ("sum", "p50", "p99"):
                v = snap.get(field)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(f"registry[{name!r}]: histogram "
                                    f"{field} {v!r} is not numeric")
        else:
            problems.append(f"registry[{name!r}]: unknown instrument "
                            f"type {kind!r}")
    stages = results.get("stages")
    if not isinstance(stages, dict) or not stages:
        problems.append(f"results.stages missing or empty "
                        f"({type(stages).__name__})")
    return problems


def _numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_mesh_section(results: dict) -> list[str]:
    """Validate the mesh serving section (``results.mesh``, written by
    ``common.smoke_mesh``): shed counts are non-negative ints that sum
    to the total, the shed rate is a fraction, at least one handoff
    happened with a well-formed pause summary, and the stage breakdown
    carries the mesh-only ``shed`` and ``handoff`` stages next to the
    serving ones.  The section is additive within repro-bench/3 —
    artifacts written before the mesh existed simply lack it and stay
    valid — but once present it must be well-formed: a drive that
    produced no sheds or no handoff means the deterministic smoke
    construction broke, which is exactly what this gate catches."""
    problems: list[str] = []
    mesh = results.get("mesh")
    if mesh is None:
        return []
    if not isinstance(mesh, dict):
        return [f"results.mesh is not a dict ({type(mesh).__name__})"]
    shed = mesh.get("shed")
    if not isinstance(shed, dict) or "total" not in shed:
        problems.append(f"mesh.shed missing or malformed: {shed!r}")
    else:
        bad = False
        for reason, v in sorted(shed.items()):
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                problems.append(f"mesh.shed[{reason!r}] = {v!r} is not "
                                "a non-negative int")
                bad = True
        if not bad:
            parts = sum(v for r, v in shed.items() if r != "total")
            if shed["total"] != parts:
                problems.append(f"mesh.shed total {shed['total']} != "
                                f"sum of per-reason counts {parts}")
            if shed["total"] == 0:
                problems.append("mesh.shed total is 0 — the smoke drive "
                                "is built to shed deterministically")
    rate = mesh.get("shed_rate")
    if not _numeric(rate) or not 0.0 <= rate <= 1.0:
        problems.append(f"mesh.shed_rate {rate!r} is not a fraction "
                        "in [0, 1]")
    handoffs = mesh.get("handoffs")
    if not isinstance(handoffs, int) or isinstance(handoffs, bool) \
            or handoffs < 1:
        problems.append(f"mesh.handoffs {handoffs!r} is not a positive "
                        "int (the mesh pins an epoch at startup)")
    pause = mesh.get("handoff_pause_us")
    if not isinstance(pause, dict):
        problems.append(f"mesh.handoff_pause_us missing or malformed: "
                        f"{pause!r}")
    else:
        if not isinstance(pause.get("count"), int) or pause["count"] < 1:
            problems.append(f"mesh.handoff_pause_us.count "
                            f"{pause.get('count')!r} is not a positive int")
        for field in ("p50", "p99"):
            v = pause.get(field)
            if not _numeric(v) or v < 0:
                problems.append(f"mesh.handoff_pause_us.{field} {v!r} "
                                "is not a non-negative number")
    stages = mesh.get("stages")
    if not isinstance(stages, dict) or not stages:
        problems.append(f"mesh.stages missing or empty "
                        f"({type(stages).__name__})")
    else:
        for required in ("shed", "handoff", "score"):
            if required not in stages:
                problems.append(f"mesh.stages missing the {required!r} "
                                "stage the smoke drive always exercises")
        for name, st in sorted(stages.items()):
            if not isinstance(st, dict) \
                    or not isinstance(st.get("count"), int) \
                    or not all(_numeric(st.get(f)) for f in ("p50", "p99")):
                problems.append(f"mesh.stages[{name!r}] is not a "
                                f"well-formed stage summary: {st!r}")
    return problems


def check_banded_section(results: dict) -> list[str]:
    """Validate the banded layout section (``results.layout_mix.banded``,
    written by ``common.smoke_layout_mix``): the per-band byte
    accounting must be internally consistent (band totals sum to the
    segment total), the HOR tail prices at exactly the HOR rate, and
    the banded build must actually compress below the HOR roofline on
    the smoke corpus — a ratio drifting to >= 1.0 means the band cut
    chooser or the packed-band builder regressed.  Additive within
    repro-bench/3: artifacts written before banding simply lack the
    key and stay valid."""
    problems: list[str] = []
    mix = results.get("layout_mix")
    if not isinstance(mix, dict):
        return []
    banded = mix.get("banded")
    if banded is None:
        return []
    if not isinstance(banded, dict):
        return [f"layout_mix.banded is not a dict "
                f"({type(banded).__name__})"]
    for field in ("band_cut", "packed_words_per_block", "posting_bytes",
                  "hor_posting_bytes"):
        v = banded.get(field)
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            problems.append(f"banded.{field} {v!r} is not a "
                            "non-negative int")
    ratio = banded.get("bytes_vs_hor")
    if not _numeric(ratio) or not 0.0 < ratio < 1.0:
        problems.append(f"banded.bytes_vs_hor {ratio!r} is not in (0, 1) "
                        "— the banded build stopped compressing")
    bands = banded.get("bands")
    if not isinstance(bands, dict) or set(bands) != {"packed", "hor"}:
        problems.append(f"banded.bands missing or malformed: {bands!r}")
        return problems
    for name, band in sorted(bands.items()):
        if not isinstance(band, dict) \
                or not isinstance(band.get("terms"), int) \
                or not isinstance(band.get("posting_bytes"), int) \
                or not _numeric(band.get("bytes_vs_hor")):
            problems.append(f"banded.bands[{name!r}] is not a well-formed "
                            f"band summary: {band!r}")
            return problems
    total = bands["packed"]["posting_bytes"] + bands["hor"]["posting_bytes"]
    if isinstance(banded.get("posting_bytes"), int) \
            and banded["posting_bytes"] != total:
        problems.append(f"banded.posting_bytes {banded['posting_bytes']} "
                        f"!= sum of band posting bytes {total}")
    if bands["hor"]["bytes_vs_hor"] != 1.0:
        problems.append(f"banded HOR tail bytes_vs_hor "
                        f"{bands['hor']['bytes_vs_hor']!r} != 1.0 — the "
                        "tail IS hor by construction")
    p_ratio = bands["packed"]["bytes_vs_hor"]
    if not 0.0 < p_ratio < 1.0:
        problems.append(f"banded packed band bytes_vs_hor {p_ratio!r} is "
                        "not in (0, 1) — band-local stride regressed")
    return problems


def check(current_path: str, baseline_path: str,
          factor: float = 2.0) -> list[str]:
    """Returns a list of failure messages (empty = gate passes)."""
    current = common.read_bench(current_path)
    baseline = common.read_bench(baseline_path)
    problems: list[str] = []
    # v3 artifacts promise an observability section; validate the
    # CURRENT artifact only (v1/v2 baselines predate the section and
    # stay loadable — READ_SCHEMAS back-compat)
    if current.get("schema") == "repro-bench/3":
        problems.extend(check_registry_section(current.get("results", {})))
        problems.extend(check_mesh_section(current.get("results", {})))
        problems.extend(check_banded_section(current.get("results", {})))
        if problems:
            return problems
    cb, bb = (current["env"].get("backend"), baseline["env"].get("backend"))
    if cb != bb:
        problems.append(f"backend mismatch: current={cb!r} "
                        f"baseline={bb!r} — refusing to compare")
        return problems
    try:
        cur_p99 = float(current["results"]["gate"]["p99_us"])
        base_p99 = float(baseline["results"]["gate"]["p99_us"])
    except KeyError as e:
        problems.append(f"missing gate stats ({e}) — artifact layout "
                        f"changed without a schema bump?")
        return problems
    if base_p99 <= 0:
        problems.append(f"baseline p99 {base_p99} is not positive")
        return problems
    ratio = cur_p99 / base_p99
    line = (f"smoke gate p99: current={cur_p99:.1f}us "
            f"baseline={base_p99:.1f}us ratio={ratio:.2f} "
            f"(limit {factor:.2f}x)")
    print(line)
    if ratio > factor:
        problems.append(f"REGRESSION: {line}")
    if current["results"].get("suites_failed"):
        problems.append(
            f"{current['results']['suites_failed']} benchmark suite(s) "
            f"failed in the smoke run")
    return problems


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--factor", type=float, default=2.0)
    args = ap.parse_args(argv)
    problems = check(args.current, args.baseline, args.factor)
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        raise SystemExit(1)
    print("gate passed")


if __name__ == "__main__":
    main()
