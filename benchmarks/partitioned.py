"""Doc- vs term-partitioned retrieval: the distribution crossover.

Runs both shard_map engines on an 8-device host mesh (subprocess, since
XLA device count must be set before jax init) and reports per-query
latency plus the ANALYTIC per-query wire bytes at production scale —
the quantity that decides the sharding choice at 1000+ nodes:

  doc-partitioned : wire/query ~ shards * k * 8 B      (top-k merge)
  term-partitioned: wire/query ~ D * 4 B               ([D] psum)
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit, is_smoke

# corpus/query sizing is injected so --smoke reaches the subprocess too
SCRIPT = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro.text import corpus
from repro.core import build
from repro.distributed import retrieval

mesh = jax.make_mesh((8,), ("data",))
tc = corpus.generate(corpus.CorpusSpec(num_docs={docs}, vocab={vocab},
                                       avg_distinct={avg}, seed=4))
host = build.bulk_build(tc)
qh = corpus.sample_query_terms(host.df, host.term_hashes, {queries}, 3,
                               num_docs=host.num_docs, seed=5)

for name, builder, mk in [
        ("doc", retrieval.build_doc_sharded,
         retrieval.make_doc_sharded_scorer),
        ("term", retrieval.build_term_sharded,
         retrieval.make_term_sharded_scorer),
        # fused engines per layout: the term-sharded tier now runs the
        # compressed layout end to end (per-shard re-compression +
        # in-VMEM decode), so the crossover is measured per layout too
        ("term_fused_hor", retrieval.build_term_sharded_blocked,
         retrieval.make_term_sharded_fused_scorer),
        ("term_fused_packed", retrieval.build_term_sharded_packed,
         retrieval.make_term_sharded_fused_scorer)]:
    ix = builder(host, 8)
    scorer = mk(ix, mesh, "data", k=10)
    scorer(jnp.asarray(qh[0]))          # warm
    t0 = time.perf_counter()
    for q in qh:
        out = scorer(jnp.asarray(q))
        jax.tree.map(lambda x: x.block_until_ready(), out)
    us = (time.perf_counter() - t0) / len(qh) * 1e6
    print(f"RESULT {name} {us:.1f}")
"""


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    sizing = (dict(docs=1_500, vocab=600, avg=25, queries=8) if is_smoke()
              else dict(docs=8000, vocab=2000, avg=60, queries=32))
    script = SCRIPT
    for key, val in sizing.items():   # not .format(): SCRIPT has f-strings
        script = script.replace("{%s}" % key, str(val))
    try:
        out = subprocess.run([sys.executable, "-c", script],
                             env=env, capture_output=True, text=True,
                             timeout=520)
        stdout, stderr = out.stdout, out.stderr
    except subprocess.TimeoutExpired as e:
        # salvage whatever engines finished (the interpret-mode fused
        # rows at full bench size can outlast the budget on slow hosts)
        stdout = (e.stdout or b"").decode() if isinstance(
            e.stdout, bytes) else (e.stdout or "")
        err = (e.stderr or b"").decode() if isinstance(
            e.stderr, bytes) else (e.stderr or "")
        stderr = "subprocess timeout: " + err
    expected = ["doc", "term", "term_fused_hor", "term_fused_packed"]
    finished = []
    for line in stdout.splitlines():
        if line.startswith("RESULT"):
            _, name, us = line.split()
            finished.append(name)
            emit(f"partitioned/{name}_sharded_8dev", float(us), "per_query")
    # a timeout salvage that silently drops engines reads as "all
    # measured" — name every dropped shard config explicitly
    dropped = [n for n in expected if n not in finished]
    for name in dropped:
        emit(f"partitioned/{name}_sharded_8dev/DROPPED", 0.0,
             "timed_out_before_measurement")
    if dropped:
        print(f"# partitioned: dropped {len(dropped)}/{len(expected)} "
              f"engine configs: {','.join(dropped)}", file=sys.stderr)
    if not finished:
        emit("partitioned/FAILED", 0.0, stderr[-200:].replace("\n", " "))

    # analytic production-scale wire (1M docs, 256 shards, k=10)
    shards, k, docs = 256, 10, 1_004_721
    emit("partitioned/analytic/doc_wire_bytes", 0.0,
         f"per_query={shards * k * 8}")
    emit("partitioned/analytic/term_wire_bytes", 0.0,
         f"per_query={docs * 4};ratio={docs * 4 / (shards * k * 8):.0f}x")
    # per-layout posting-HBM bytes for the sharded fused engines live in
    # roofline.py (query_bytes/{doc,term}_sharded_{hor,packed} rows) —
    # this benchmark owns the latency/wire side of the crossover


if __name__ == "__main__":
    main()
