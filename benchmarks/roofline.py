"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch x shape x mesh) cell, derive the three roofline terms
from the compiled program:

  compute    = FLOPs / (chips x 197e12)
  memory     = HBM bytes / (chips x 819e9)
  collective = ICI wire bytes / (chips x 50e9 x links)

Methodology (CPU container — no wall-clock MFU possible):
  * FLOPs: XLA's ``cost_analysis`` counts while-loop bodies ONCE, so for
    scanned models it under-counts by ~n_layers; we therefore use the
    ANALYTIC model FLOPs (6·N·D train / 2·N·D inference, documented per
    family in configs/base.py meta) as the compute numerator and report
    HLO_flops alongside as the "per-trip" count.
  * HBM bytes: optimistic lower bound = every argument read once +
    outputs written once + temp buffers written+read once (buffer sizes
    from ``memory_analysis``), plus for decode cells the KV cache read.
  * ICI bytes: a WHILE-AWARE walk of the optimized HLO — collectives
    inside loop bodies are multiplied by the loop trip count (parsed
    from the loop condition's comparison constant), with per-op wire
    factors: all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all
    (n-1)/n, collective-permute 1.

Engines (the ``query_bytes`` rows, emitted by ``query_hbm_bytes``):
  the fused decode-and-score engine's per-query HBM traffic has a READ
  side and a WRITE side, reported separately.
  * Read rows ``query_bytes/hor`` vs ``query_bytes/packed``: posting
    payload bytes for a sampled batch with cross-query block dedup —
    the paper's §4.3 layout-determines-I/O claim (packed streams
    <= ~0.5x of unpacked HOR).
  * Write rows ``query_bytes/score_dense`` vs
    ``query_bytes/score_candidates``: the PR-1 dense engine wrote a
    ``[Q, num_docs]`` f32 score array to HBM before ``top_k``
    (4·num_docs B/query — at corpus scale this write dwarfs the
    compressed posting bytes the read side saved); the candidate
    engine reduces each doc tile to ``k_tile`` (f32 value, i32 doc id)
    pairs IN VMEM, so only 8·n_tiles·k_tile B/query reach HBM — the
    write scales with ``n_tiles * k_tile``, not ``num_docs``.

Emits one CSV row per cell and writes experiments/roofline.csv.
"""
from __future__ import annotations

import glob
import json
import os
import re

import numpy as np

from repro.launch import hw

BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4,
         "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


class HloModule:
    """Minimal HLO text parser: computations, collectives, while loops."""

    def __init__(self, text: str, n_devices: int):
        self.n_devices = n_devices
        self.comp_collectives: dict[str, list[tuple[str, int, int]]] = {}
        self.comp_whiles: dict[str, list[tuple[str, str]]] = {}
        self.comp_consts: dict[str, list[int]] = {}
        self.entry = None
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            # computation headers sit at column 0: "%name (args...) -> T {"
            # (args may contain nested parens -> match only the name)
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line) \
                if line and not line.startswith(" ") and \
                line.rstrip().endswith("{") else None
            if m:
                cur = m.group(2)
                self.comp_collectives[cur] = []
                self.comp_whiles[cur] = []
                self.comp_consts[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            for c in COLLECTIVES:
                if f" {c}(" in stripped and "=" in stripped:
                    lhs = stripped.split(f" {c}(", 1)[0]
                    b = sum(_shape_bytes(mm.group(1), mm.group(2))
                            for mm in _SHAPE_RE.finditer(lhs))
                    self.comp_collectives[cur].append(
                        (c, b, _group_size(stripped, self.n_devices)))
                    break
            mw = re.search(r"while\(.*\), condition=%?([\w.\-]+), "
                           r"body=%?([\w.\-]+)", stripped)
            if mw:
                self.comp_whiles[cur].append((mw.group(1), mw.group(2)))
            for mc in re.finditer(r"constant\((\d+)\)", stripped):
                self.comp_consts[cur].append(int(mc.group(1)))

    def trip_count(self, cond: str) -> int:
        consts = self.comp_consts.get(cond, [])
        return max(consts) if consts else 1

    def wire_bytes(self, comp: str | None = None, mult: float = 1.0,
                   seen=None) -> float:
        comp = comp or self.entry
        if comp is None or comp not in self.comp_collectives:
            return 0.0
        seen = seen or set()
        total = 0.0
        for op, b, n in self.comp_collectives[comp]:
            factor = WIRE_FACTOR[op] * (max(n - 1, 0) / max(n, 1))
            total += mult * b * factor
        for cond, body in self.comp_whiles[comp]:
            trips = self.trip_count(cond)
            total += self.wire_bytes(body, mult * trips, seen)
        return total


def analyze_cell(path: str) -> dict | None:
    with open(path) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return None
    mesh = rec["mesh_shape"]
    chips = int(np.prod(list(mesh.values())))
    meta = rec["meta"]
    mem = rec["memory"]

    model_flops = meta["model_flops"]
    hlo_flops_trip = rec["cost"].get("flops", 0.0) * chips

    # memory term: args once + out once + temps twice, per device
    hbm_bytes = (mem["argument_size_in_bytes"] +
                 mem["output_size_in_bytes"] +
                 2 * mem["temp_size_in_bytes"])
    t_mem = hbm_bytes / hw.HBM_BW

    t_comp = model_flops / (chips * hw.PEAK_FLOPS_BF16)

    hlo_path = path.replace(".json", ".hlo.txt")
    t_coll = 0.0
    wire = 0.0
    if os.path.exists(hlo_path):
        mod = HloModule(open(hlo_path).read(), chips)
        wire = mod.wire_bytes()          # per-device wire bytes
        t_coll = wire / hw.ICI_BW

    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "model_flops": model_flops,
        "hlo_flops_per_trip": hlo_flops_trip,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "hbm_bytes_per_dev": hbm_bytes, "wire_bytes_per_dev": wire,
        "dominant": dominant,
        "roofline_fraction": frac,
        "useful_ratio": (model_flops / hlo_flops_trip
                         if hlo_flops_trip else float("nan")),
    }


def query_hbm_bytes(n_queries: int = 8, n_terms: int = 4,
                    k: int = 10) -> None:
    """Measured posting-HBM bytes per query for the fused engine.

    READ side: payload bytes the fused decode-and-score engine streams
    for a sampled batch — each unique posting block touched by the batch
    is read ONCE (cross-query dedup).  HOR streams raw int32 doc ids +
    f32 tfs (8 B/posting); Packed streams the bit-packed words + f16 tfs
    (+12 B of per-block decode scalars) — the paper's §4.3 I/O argument,
    measured.  The packed/HOR ratio should be <= ~0.5.

    WRITE side (the ranking tail): dense engine = 4·num_docs B/query of
    f32 scores; candidate engine = 8·n_tiles·k_tile B/query of (value,
    doc id) pairs — scaling with the tile grid and per-tile candidate
    count instead of the corpus size.
    """
    from benchmarks.common import bench_host, emit
    from repro.core import layouts
    from repro.kernels.fused_decode_score import TILE, default_k_tile
    from repro.text import corpus

    _, host = bench_host()
    hor = layouts.build_blocked(host)
    packed = layouts.build_packed_csr(host)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, n_queries,
                                   n_terms, num_docs=host.num_docs, seed=7)
    blocks = _touched_blocks(hor, qh)
    hor_bytes = _blocked_payload_bytes(hor, blocks)
    packed_bytes = _packed_payload_bytes(packed, blocks)
    ratio = packed_bytes / max(hor_bytes, 1)
    emit("roofline/query_bytes/hor", 0.0,
         f"bytes_per_query={hor_bytes / n_queries:.0f};"
         f"blocks={len(blocks)}")
    emit("roofline/query_bytes/packed", 0.0,
         f"bytes_per_query={packed_bytes / n_queries:.0f};"
         f"ratio_vs_hor={ratio:.3f}")

    sharded_query_hbm_bytes(host, qh, n_queries)

    # score-WRITE bytes per query: dense [Q, num_docs] f32 vs the
    # candidate engine's per-tile (f32 value, i32 doc id) pairs
    num_docs = host.num_docs
    n_tiles = max(-(-num_docs // TILE), 1)
    k_tile = default_k_tile(k, TILE)
    dense_bytes = num_docs * 4
    cand_bytes = n_tiles * k_tile * 8
    emit("roofline/query_bytes/score_dense", 0.0,
         f"bytes_per_query={dense_bytes};num_docs={num_docs}")
    emit("roofline/query_bytes/score_candidates", 0.0,
         f"bytes_per_query={cand_bytes};n_tiles={n_tiles};"
         f"k_tile={k_tile};k={k};"
         f"ratio_vs_dense={cand_bytes / max(dense_bytes, 1):.4f}")


def _blocked_payload_bytes(ix, blocks: np.ndarray) -> int:
    """HOR posting payload for a set of touched blocks: raw int32 doc
    ids + f32 tfs, 8 B per lane."""
    block = ix.block
    return len(blocks) * (block * 4 + block * 4)


def _packed_payload_bytes(ix, blocks: np.ndarray) -> int:
    """Packed posting payload for a set of touched blocks: the
    bit-packed words + f16 tfs + 12 B of per-block decode scalars
    (bits/base/count) — the bytes the in-VMEM decoder actually streams."""
    block = ix.block
    bits = np.asarray(ix.block_bits)[blocks]
    return int(np.sum((block * bits + 31) // 32 * 4)
               + len(blocks) * (block * 2 + 12))


def _touched_blocks(ix, qh: np.ndarray) -> np.ndarray:
    """Unique posting blocks a query batch touches in one (sub-)index —
    cross-query dedup, exactly like the fused engine's pair dedup."""
    sorted_hash = np.asarray(ix.sorted_hash)
    offsets = np.asarray(ix.block_offsets)
    blocks = set()
    for q in qh:
        for h in q:
            pos = int(np.searchsorted(sorted_hash, h))
            if pos < len(sorted_hash) and sorted_hash[pos] == h:
                blocks.update(range(offsets[pos], offsets[pos + 1]))
    return np.array(sorted(blocks), dtype=np.int64)


def sharded_query_hbm_bytes(host, qh: np.ndarray, n_queries: int,
                            n_shards: int = 4) -> None:
    """Posting-HBM bytes per query for the SHARDED fused engines, per
    layout per sharding mode.

    TERM-sharded: each vocab shard re-compresses its whole posting
    lists (global doc ids) — a query streams the touched blocks of the
    shards owning its terms; bytes are summed over shards.  DOC-sharded:
    every shard re-packs its document slice (local ids, so packed deltas
    shrink) and a query broadcasts to ALL shards.  In both modes the
    packed/HOR ratio should hold at <= ~0.5 — the acceptance bar for the
    compressed layout being a first-class citizen of the distributed
    tier, not just the single-node engine.
    """
    from benchmarks.common import emit
    from repro.core import layouts
    from repro.core.layouts import PostingsHost

    # -- term-sharded: per-vocab-shard re-compression (whole lists) ------
    from repro.distributed.retrieval import _term_shard_subhosts
    subs, _ = _term_shard_subhosts(host, n_shards)
    totals = {"hor": 0, "packed": 0}
    for sub in subs:
        hor = layouts.build_blocked(sub)
        packed = layouts.build_packed_csr(sub)
        blocks = _touched_blocks(hor, qh)
        totals["hor"] += _blocked_payload_bytes(hor, blocks)
        totals["packed"] += _packed_payload_bytes(packed, blocks)
    emit("roofline/query_bytes/term_sharded_hor", 0.0,
         f"bytes_per_query={totals['hor'] / n_queries:.0f};"
         f"shards={n_shards}")
    emit("roofline/query_bytes/term_sharded_packed", 0.0,
         f"bytes_per_query={totals['packed'] / n_queries:.0f};"
         f"ratio_vs_hor={totals['packed'] / max(totals['hor'], 1):.3f}")

    # -- doc-sharded: per-doc-slice re-pack (local ids, smaller deltas) --
    bounds = np.linspace(0, host.num_docs, n_shards + 1).astype(np.int64)
    term_of = np.repeat(np.arange(host.num_terms, dtype=np.int64),
                        np.diff(host.offsets))
    totals = {"hor": 0, "packed": 0}
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        m = (host.doc_ids >= lo) & (host.doc_ids < hi)
        order = np.lexsort((host.doc_ids[m], term_of[m]))
        df_l = np.bincount(term_of[m],
                           minlength=host.num_terms).astype(np.int64)
        offs = np.zeros(host.num_terms + 1, dtype=np.int64)
        np.cumsum(df_l, out=offs[1:])
        sub = PostingsHost(
            term_hashes=host.term_hashes, df=df_l.astype(np.int32),
            offsets=offs,
            doc_ids=(host.doc_ids[m][order] - lo).astype(np.int32),
            tfs=host.tfs[m][order].astype(np.float32),
            num_docs=int(hi - lo), norm=host.norm[lo:hi],
            rank=host.rank[lo:hi])
        hor = layouts.build_blocked(sub)
        packed = layouts.build_packed_csr(sub)
        blocks = _touched_blocks(hor, qh)
        totals["hor"] += _blocked_payload_bytes(hor, blocks)
        totals["packed"] += _packed_payload_bytes(packed, blocks)
    emit("roofline/query_bytes/doc_sharded_hor", 0.0,
         f"bytes_per_query={totals['hor'] / n_queries:.0f};"
         f"shards={n_shards}")
    emit("roofline/query_bytes/doc_sharded_packed", 0.0,
         f"bytes_per_query={totals['packed'] / n_queries:.0f};"
         f"ratio_vs_hor={totals['packed'] / max(totals['hor'], 1):.3f}")


def main(out_dir: str = "experiments/dryrun",
         csv_path: str = "experiments/roofline.csv") -> None:
    query_hbm_bytes()
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*", "*.json"))):
        r = analyze_cell(path)
        if r:
            rows.append(r)
    if not rows:
        print("roofline/no_dryrun_artifacts,0.0,run launch.dryrun first")
        return
    os.makedirs(os.path.dirname(csv_path), exist_ok=True)
    keys = list(rows[0].keys())
    with open(csv_path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    for r in rows:
        name = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        us = max(r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"]) * 1e6
        print(f"{name},{us:.1f},dom={r['dominant']};"
              f"frac={r['roofline_fraction']:.3f};"
              f"comp={r['t_compute_s']:.2e};mem={r['t_memory_s']:.2e};"
              f"coll={r['t_collective_s']:.2e}")
    print(f"roofline/csv,0.0,{csv_path};cells={len(rows)}")


if __name__ == "__main__":
    main()
