"""Paper Table 5: table sizes + bulk build ("copy") times per
representation, at the CPU bench tier AND analytically at paper scale.

Also the calibration table for the adaptive layout chooser: each
layout's MEASURED posting-array bytes next to the ``size_model``
analytic prediction with a relative-error column — the same estimators
``LayoutCostModel`` scores seals and compactions with, so a drifting
prediction shows up here before it misroutes a layout decision."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_host, emit, time_host
from repro.core import build, layouts
from repro.core import size_model as sm


def main() -> None:
    tc, host = bench_host()
    stats = build.corpus_stats(host)
    run = sm.SegmentStats(host.num_docs, host.num_postings,
                          int(np.count_nonzero(host.df)))

    builders = {
        "pr": layouts.build_coo,
        "or": layouts.build_csr,
        "cor": layouts.build_compact_csr,
        "hor": layouts.build_blocked,
        "packed": layouts.build_packed_csr,
        "banded": layouts.build_banded,
    }
    pr_bytes = None
    for name, bld in builders.items():
        us = time_host(lambda b=bld: b(host), reps=1)
        ix = bld(host)
        nbytes = ix.nbytes()
        if name == "pr":
            pr_bytes = nbytes
        emit(f"table5/size/{name}", us,
             f"bytes={nbytes};ratio_vs_pr={pr_bytes / nbytes:.2f}")
        # measured posting arrays vs the chooser's analytic estimator
        measured = ix.posting_bytes()
        predicted = sm.est_posting_bytes(run, name)
        rel_err = (predicted - measured) / measured
        emit(f"table5/predict/{name}", 0.0,
             f"measured={measured};predicted={predicted};"
             f"rel_err={rel_err:+.3f}")

    # the chooser's exact hor formula (per-term df, no aggregate
    # approximation) must match the built arrays to the byte
    hor_exact = sm.hor_posting_bytes_from_df(host.df)
    hor_meas = layouts.build_blocked(host).posting_bytes()
    emit("table5/predict/hor_exact", 0.0,
         f"measured={hor_meas};predicted={hor_exact};"
         f"rel_err={(hor_exact - hor_meas) / hor_meas:+.3f}")

    # ... and the exact-width banded formula: the per-term packed
    # widths drive both the cut choice and the byte count, so predicted
    # must equal the built arrays to the byte (rel_err +0.000)
    words, nblocks = layouts.term_packed_words(host)
    cut, banded_exact = sm.choose_band_cut(words, nblocks)
    banded_meas = layouts.build_banded(host).posting_bytes()
    emit("table5/predict/banded_exact", 0.0,
         f"measured={banded_meas};predicted={banded_exact};cut={cut};"
         f"rel_err={(banded_exact - banded_meas) / banded_meas:+.3f}")

    # the bulk sort itself (the §3.6 COPY path)
    us = time_host(lambda: build.bulk_build(tc), reps=1)
    emit("table5/bulk_build", us,
         f"postings={stats.N_d};per_posting_ns={us * 1e3 / stats.N_d:.1f}")

    # analytic paper-scale reproduction (Table 4/5)
    p = sm.PAPER_COLLECTION
    emit("table5/analytic/pr_bytes", 0.0, f"bytes={sm.pr_bytes(p)}")
    emit("table5/analytic/orif_bytes", 0.0, f"bytes={sm.orif_bytes(p)}")
    emit("table5/analytic/pr_over_orif", 0.0,
         f"ratio={sm.pr_over_orif(p):.2f}")
    emit("table5/analytic/packed_bytes", 0.0,
         f"bytes={sm.packed_csr_layout_bytes(p)};"
         f"pr_over_packed={sm.pr_bytes(p) / sm.packed_csr_layout_bytes(p):.2f}")
    emit("table5/paper_measured", 0.0,
         "pr_pages=1338589;orif_pages=65509;ratio=20.4")


if __name__ == "__main__":
    main()
