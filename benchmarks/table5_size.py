"""Paper Table 5: table sizes + bulk build ("copy") times per
representation, at the CPU bench tier AND analytically at paper scale."""
from __future__ import annotations

from benchmarks.common import bench_host, emit, time_host
from repro.core import build, layouts
from repro.core import size_model as sm


def main() -> None:
    tc, host = bench_host()
    stats = build.corpus_stats(host)

    builders = {
        "pr": layouts.build_coo,
        "or": layouts.build_csr,
        "cor": layouts.build_compact_csr,
        "hor": layouts.build_blocked,
        "packed": layouts.build_packed_csr,
    }
    pr_bytes = None
    for name, bld in builders.items():
        us = time_host(lambda b=bld: b(host), reps=1)
        ix = bld(host)
        nbytes = ix.nbytes()
        if name == "pr":
            pr_bytes = nbytes
        emit(f"table5/size/{name}", us,
             f"bytes={nbytes};ratio_vs_pr={pr_bytes / nbytes:.2f}")

    # the bulk sort itself (the §3.6 COPY path)
    us = time_host(lambda: build.bulk_build(tc), reps=1)
    emit("table5/bulk_build", us,
         f"postings={stats.N_d};per_posting_ns={us * 1e3 / stats.N_d:.1f}")

    # analytic paper-scale reproduction (Table 4/5)
    p = sm.PAPER_COLLECTION
    emit("table5/analytic/pr_bytes", 0.0, f"bytes={sm.pr_bytes(p)}")
    emit("table5/analytic/orif_bytes", 0.0, f"bytes={sm.orif_bytes(p)}")
    emit("table5/analytic/pr_over_orif", 0.0,
         f"ratio={sm.pr_over_orif(p):.2f}")
    emit("table5/analytic/packed_bytes", 0.0,
         f"bytes={sm.packed_csr_layout_bytes(p)};"
         f"pr_over_packed={sm.pr_bytes(p) / sm.packed_csr_layout_bytes(p):.2f}")
    emit("table5/paper_measured", 0.0,
         "pr_pages=1338589;orif_pages=65509;ratio=20.4")


if __name__ == "__main__":
    main()
